"""L2 — loss, metrics, SGD-momentum training step (paper Algorithm 1).

The exported train step is *functional*: every piece of mutable state
(params, momentum velocities, BN running stats) is an explicit input and
output, so the rust coordinator owns all state across steps and the HLO
artifact is a pure function.

    train_step(params, vel, bn, bn_state, wps, rs, x, y, gamma, lr, step)
      -> (params', vel', bn', bn_state', loss, acc, mask_densities...)

Backward masking (Algorithm 1's forced gradient sparsification at every
mask layer) falls out of jax.grad through the multiplicative masks: the
mask tensors are stop-gradient constants, so dL/dS is exactly
Mask * (upstream), as in the paper.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from . import models as M

MOMENTUM = 0.9


def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; y is int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    n = logits.shape[0]
    picked = logp[jnp.arange(n), y]
    return -jnp.mean(picked)


def accuracy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def loss_fn(model, params, bn, bn_state, wps, rs, x, y, gamma, step):
    logits, new_bn_state, densities = M.forward(
        model, params, bn, bn_state, wps, rs, x, gamma, train=True, step=step
    )
    loss = cross_entropy(logits, y)
    return loss, (new_bn_state, accuracy(logits, y), densities)


def sgd_momentum(params, vel, grads, lr):
    """v <- mu v - lr g;  w <- w + v   (applied leaf-wise on the pytree)."""

    def upd(v, g):
        return MOMENTUM * v - lr * g

    new_vel = jax.tree_util.tree_map(upd, vel, grads)
    new_params = jax.tree_util.tree_map(lambda w, v: w + v, params, new_vel)
    return new_params, new_vel


def make_train_step(model: M.Model):
    """Build the pure train-step function for ``model`` (jit-able)."""

    def train_step(params, vel, bn, vbn, bn_state, wps, rs, x, y, gamma, lr, step):
        (loss, (new_bn_state, acc, dens)), grads = jax.value_and_grad(
            lambda p, b: loss_fn(
                model, p, b, bn_state, wps, rs, x, y, gamma, step
            ),
            argnums=(0, 1),
            has_aux=True,
        )(params, bn)
        gp, gb = grads
        new_params, new_vel = sgd_momentum(params, vel, gp, lr)
        new_bn, new_vbn = sgd_momentum(bn, vbn, gb, lr)
        return (
            new_params,
            new_vel,
            new_bn,
            new_vbn,
            new_bn_state,
            loss,
            acc,
            dens,
        )

    return train_step


def make_forward(model: M.Model):
    """Inference/eval function: running-stat BN, no state mutation."""

    def fwd(params, bn, bn_state, wps, rs, x, gamma):
        logits, _, dens = M.forward(
            model,
            params,
            bn,
            bn_state,
            wps,
            rs,
            x,
            gamma,
            train=False,
            step=jnp.int32(0),
        )
        return logits, dens

    return fwd


def make_project(model: M.Model):
    """The every-50-steps Wp refresh (rust schedules when to call it)."""

    def project(params, rs):
        return M.project_all(model, params, rs)

    return project


def init_velocities(params) -> List:
    return jax.tree_util.tree_map(jnp.zeros_like, params)
