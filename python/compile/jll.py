"""Johnson-Lindenstrauss projection-dimension model (paper §2.2, Table 1).

The paper picks the reduced dimension ``k`` from the JLL bound
``k > O(log(N) / eps^2)``.  The hidden constants are calibrated against
the paper's own Table 1, whose "Dimension" rows depend only on the number
of output neurons n_K (rows sharing n_K share k across different n_CRS):

    k(eps, n_K) = ceil( ln(n_K) * (C1 / eps^2 + C2) )

Least-squares fit over Table 1 gives C1 = 8.9, C2 = 12.3; residuals are
<= 1 unit for eps in {0.3, 0.5, 0.7} and <= 6% at eps = 0.9 (the paper's
own 0.9 column is slightly above any k = a/eps^2 + b curve).  The same
constants are mirrored in rust/src/costmodel/jll.rs; test_jll.py and the
rust unit tests pin both to the published table.
"""

from __future__ import annotations

import math

C1 = 8.9
C2 = 12.3


def projection_dim(eps: float, n_out: int, d_in: int) -> int:
    """Reduced dimension k for a layer with d_in inputs, n_out outputs.

    Clipped to [1, d_in]: when the calibrated k would exceed the original
    dimension (tiny layers), projection is pointless and we keep k = d_in
    (the map degenerates to a rotation-free estimate of the same cost).
    """
    if not (0.0 < eps < 1.0):
        raise ValueError(f"eps must be in (0,1), got {eps}")
    if n_out < 1 or d_in < 1:
        raise ValueError(f"bad layer dims n_out={n_out} d_in={d_in}")
    k = math.ceil(math.log(max(n_out, 2)) * (C1 / (eps * eps) + C2))
    return max(1, min(k, d_in))


def search_mmacs(n_pq: int, k: int, n_k: int) -> float:
    """Table 1 'Operations' column: low-dim VMM cost in Mi-MACs (2^20).

    The ternary projection itself is multiplication-free (eq. 6), so the
    paper counts only the low-dimensional virtual VMM: n_PQ * k * n_K.
    """
    return n_pq * k * n_k / float(1 << 20)


def baseline_mmacs(n_pq: int, n_crs: int, n_k: int) -> float:
    """Table 1 baseline: full VMM cost n_PQ * n_CRS * n_K in Mi-MACs."""
    return n_pq * n_crs * n_k / float(1 << 20)
