"""AOT export: lower every model variant's train/forward/project functions
to HLO **text** and emit the buffer-layout meta JSON the rust runtime uses.

Interchange is HLO text, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; python never appears on the request path.

Outputs (per variant, under artifacts/):
  <name>.train.hlo.txt     functional train step (Algorithm 1)
  <name>.fwd.hlo.txt       inference/eval forward
  <name>.project.hlo.txt   Wp refresh (drs variants only; rust schedules it)
  <name>.meta.json         flat buffer layout + init specs + file names
  <name>.probe.hlo.txt     forward that also returns full masks (probe set)
  golden/*                 cross-language golden vectors for rust tests
  kernels/*                standalone L1 kernel artifacts + goldens
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import layers as L
from . import models as M
from . import train as T
from .kernels import masked_matmul as mm
from .kernels import projection as pj


# ---------------------------------------------------------------------------
# HLO text lowering (the gen_hlo.py recipe)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> list:
    """Lower and write; returns the kept flat-input indices.

    XLA DCEs unused inputs out of the lowered signature (e.g. the `step`
    scalar in non-random variants, wps/rs in dense ones); the rust runtime
    must supply exactly the kept inputs, so we record their indices.
    """
    lowered = jax.jit(fn).lower(*example_args)
    n_flat = len(jax.tree_util.tree_leaves(example_args))
    kept = lowered._lowering.compile_args.get("kept_var_idx")
    kept = sorted(kept) if kept is not None else list(range(n_flat))
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return kept


# ---------------------------------------------------------------------------
# Flat leaf naming / meta description
# ---------------------------------------------------------------------------

_DTYPE = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "key"):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return ".".join(parts)


def leaves_with_names(tree, group: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((f"{group}.{_path_str(path)}", leaf))
    return out


def _init_spec(name: str, leaf) -> dict:
    """Infer the init recipe for a state leaf (mirrored by rust init.rs)."""
    shape = list(leaf.shape)
    if name.startswith(("vel.", "vbn.")):
        return {"kind": "zeros"}
    if name.startswith("bn_state."):
        return {"kind": "ones"} if name.endswith(".var") else {"kind": "zeros"}
    if name.startswith("bn."):
        return {"kind": "ones"} if name.endswith(".scale") else {"kind": "zeros"}
    if name.startswith("r."):
        return {"kind": "ternary", "s": 3}
    if name.endswith(".b"):
        return {"kind": "zeros"}
    if name.endswith(".w"):
        if len(shape) == 4:
            fan_in = shape[1] * shape[2] * shape[3]
        else:
            fan_in = shape[0]
        return {"kind": "he_normal", "fan_in": fan_in}
    return {"kind": "zeros"}


def describe(leaves) -> list:
    out = []
    for name, leaf in leaves:
        out.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": _DTYPE[leaf.dtype],
                "init": _init_spec(name, leaf),
            }
        )
    return out


def sds(tree):
    """Pytree of arrays -> pytree of ShapeDtypeStructs (for .lower)."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )


# ---------------------------------------------------------------------------
# Variant registry
# ---------------------------------------------------------------------------


def build_variants(fast: bool):
    """(exported name, Model, emit_probe) triples; see DESIGN.md E1-E17."""
    out = []

    def add(model, probe=False):
        out.append((model.name, model, probe))

    add(M.get("mlp"), probe=True)
    add(M.get("mlp").with_opts(strategy="dense").renamed("mlp_dense"))
    add(M.get("lenet"), probe=True)
    add(M.get("lenet").with_opts(strategy="dense").renamed("lenet_dense"))
    if fast:
        return out

    add(M.get("vgg8"))  # lite width 32
    add(M.get("vgg8").with_opts(strategy="dense").renamed("vgg8_dense"))
    add(M.get("resnet8"))
    add(M.get("resnet8").with_opts(strategy="dense").renamed("resnet8_dense"))
    add(M.get("wrn8_2"))

    # Fig 5c/5e ablations on a slimmer vgg8 (w=16) to bound bench runtime.
    s = M.vgg8(width=16, name="vgg8s")
    add(s)
    add(s.with_opts(strategy="oracle").renamed("vgg8s_oracle"))
    add(s.with_opts(strategy="random").renamed("vgg8s_random"))
    add(s.with_opts(double_mask=False).renamed("vgg8s_single"))
    add(s.with_opts(use_bn=False).renamed("vgg8s_nobn"))
    add(s.with_opts(strategy="dense").renamed("vgg8s_dense"))

    # Fig 5d: epsilon sweep (k changes => static shape change per artifact).
    for eps in (0.3, 0.7, 0.9):
        add(s.with_opts(eps=eps).renamed(f"vgg8s_eps{int(eps * 100)}"))

    # Fig 8b / Fig 12: smaller-dense models with equivalent effective MACs
    # (width ~ w * sqrt(1-gamma) for gamma in {0.5, 0.8}).
    add(M.vgg8(width=23, name="vgg8_d23").with_opts(strategy="dense"))
    add(M.vgg8(width=14, name="vgg8_d14").with_opts(strategy="dense"))
    add(M.resnet8(width=11, name="resnet8_d11").with_opts(strategy="dense"))
    add(M.resnet8(width=7, name="resnet8_d7").with_opts(strategy="dense"))
    return out


# ---------------------------------------------------------------------------
# Per-variant export
# ---------------------------------------------------------------------------


def make_project_flat(model):
    """project(ws, rs) -> wps over the flat dsg-weight list."""
    specs = M.dsg_specs(model)

    def project(ws, rs):
        wps = []
        for (path, spec), w, r in zip(specs, ws, rs):
            if isinstance(spec, L.Conv):
                wmat = w.reshape(w.shape[0], -1).T
            else:
                wmat = w
            wps.append(pj.project_weights(r, wmat))
        return wps

    return project


def unit_topology(model) -> list:
    """Serializable unit list so the rust NATIVE inference engine
    (rust/src/native/) can replay the exact forward topology with real
    column skipping — the bridge between the Fig 8 engine and real models."""
    units = []
    for u in model.units:
        if isinstance(u, L.Dense):
            units.append(
                {
                    "kind": "classifier" if u.classifier else "dense",
                    "d_in": u.d_in,
                    "d_out": u.d_out,
                }
            )
        elif isinstance(u, L.Conv):
            units.append(
                {
                    "kind": "conv",
                    "c_in": u.c_in,
                    "c_out": u.c_out,
                    "ksize": u.ksize,
                    "stride": u.stride,
                    "pad": u.pad,
                }
            )
        elif isinstance(u, L.Residual):
            units.append(
                {
                    "kind": "residual",
                    "c_in": u.c_in,
                    "c_out": u.c_out,
                    "stride": u.stride,
                }
            )
        elif isinstance(u, L.MaxPool):
            units.append({"kind": "maxpool", "size": u.size})
        elif isinstance(u, L.GlobalAvgPool):
            units.append({"kind": "gap"})
        elif isinstance(u, L.Flatten):
            units.append({"kind": "flatten"})
        else:
            raise TypeError(f"unknown unit {u}")
    return units


def dsg_weight_names(model) -> list:
    """params-group leaf names of each DSG layer's weight, in dsg order."""
    names = []
    for i, u in enumerate(model.units):
        if isinstance(u, L.Dense) and not u.classifier:
            names.append(f"params.{i}.w")
        elif isinstance(u, L.Conv):
            names.append(f"params.{i}.w")
        elif isinstance(u, L.Residual):
            names.append(f"params.{i}.conv1.w")
            names.append(f"params.{i}.conv2.w")
    return names


def export_variant(name: str, model: M.Model, out_dir: str, probe: bool) -> dict:
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, model)
    bn = M.init_bn(model)
    bn_state = M.init_bn_state(model)
    vel = T.init_velocities(params)
    vbn = T.init_velocities(bn)
    is_drs = model.opts.strategy == "drs"
    rs = M.init_projections(key, model) if is_drs else []
    wps = M.project_all(model, params, rs) if is_drs else []

    x = jnp.zeros((model.batch,) + model.input_shape, jnp.float32)
    y = jnp.zeros((model.batch,), jnp.int32)
    gamma = jnp.float32(0.5)
    lr = jnp.float32(0.05)
    step = jnp.int32(0)

    state_leaves = (
        leaves_with_names(params, "params")
        + leaves_with_names(vel, "vel")
        + leaves_with_names(bn, "bn")
        + leaves_with_names(vbn, "vbn")
        + leaves_with_names(bn_state, "bn_state")
    )
    wp_leaves = leaves_with_names(wps, "wp")
    r_leaves = leaves_with_names(rs, "r")

    files = {}
    kept = {}
    t0 = time.time()
    train_fn = T.make_train_step(model)
    train_args = (params, vel, bn, vbn, bn_state, wps, rs, x, y, gamma, lr, step)
    files["train"] = f"{name}.train.hlo.txt"
    kept["train"] = lower_to_file(
        train_fn, sds(train_args), os.path.join(out_dir, files["train"])
    )

    fwd_fn = T.make_forward(model)
    fwd_args = (params, bn, bn_state, wps, rs, x, gamma)
    files["forward"] = f"{name}.fwd.hlo.txt"
    kept["forward"] = lower_to_file(
        fwd_fn, sds(fwd_args), os.path.join(out_dir, files["forward"])
    )

    if is_drs:
        proj_fn = make_project_flat(model)
        ws = [
            dict(state_leaves)[n] for n in dsg_weight_names(model)
        ]
        files["project"] = f"{name}.project.hlo.txt"
        kept["project"] = lower_to_file(
            proj_fn, (sds(ws), sds(rs)), os.path.join(out_dir, files["project"])
        )

    if probe and is_drs:

        def probe_fn(params, bn, bn_state, wps, rs, x, gamma):
            cap = []
            logits, _, _ = M.forward(
                model,
                params,
                bn,
                bn_state,
                wps,
                rs,
                x,
                gamma,
                train=False,
                step=jnp.int32(0),
                capture=cap,
            )
            return (logits, *cap)

        files["probe"] = f"{name}.probe.hlo.txt"
        kept["probe"] = lower_to_file(
            probe_fn, sds(fwd_args), os.path.join(out_dir, files["probe"])
        )

    n_params = len(leaves_with_names(params, "params"))
    n_vel = len(leaves_with_names(vel, "vel"))
    n_bn = len(leaves_with_names(bn, "bn"))
    n_vbn = len(leaves_with_names(vbn, "vbn"))
    n_bn_state = len(leaves_with_names(bn_state, "bn_state"))
    state_names = [n for n, _ in state_leaves]
    dsg_w_names = dsg_weight_names(model) if is_drs else []
    meta = {
        "name": name,
        "base_model": model.name,
        "batch": model.batch,
        "input_shape": list(model.input_shape),
        "classes": model.n_classes,
        "opts": dataclasses.asdict(model.opts),
        "files": files,
        "kept": kept,
        "units": unit_topology(model),
        "counts": {
            "params": n_params,
            "vel": n_vel,
            "bn": n_bn,
            "vbn": n_vbn,
            "bn_state": n_bn_state,
            "wps": len(wp_leaves),
            "rs": len(r_leaves),
            "dsg": len(M.dsg_specs(model)),
        },
        "state": describe(state_leaves),
        "wps": describe(wp_leaves),
        "rs": describe(r_leaves),
        "dsg_weight_indices": [state_names.index(n) for n in dsg_w_names],
        "dsg_layers": [
            {"path": p, "k": k, "d_in": d, "n_out": n}
            for p, k, d, n in (M.projection_shapes(model) if is_drs else [])
        ],
    }
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  {name}: {len(files)} artifacts in {time.time() - t0:.1f}s")
    return meta


# ---------------------------------------------------------------------------
# Golden vectors (rust integration tests compare against these)
# ---------------------------------------------------------------------------


def _write_golden(path_base: str, tensors: list):
    """tensors: [(name, np.ndarray)] -> .bin (raw LE) + .json (index)."""
    index = []
    offset = 0
    with open(path_base + ".bin", "wb") as f:
        for name, arr in tensors:
            arr = np.asarray(arr)
            if arr.dtype == np.float32:
                dt = "f32"
            elif arr.dtype == np.int32:
                dt = "s32"
            else:
                raise TypeError(f"golden dtype {arr.dtype}")
            raw = arr.tobytes()  # C-order little-endian
            f.write(raw)
            index.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": dt,
                    "offset": offset,
                    "nbytes": len(raw),
                }
            )
            offset += len(raw)
    with open(path_base + ".json", "w") as f:
        json.dump(index, f, indent=1)


def export_golden_mlp(out_dir: str):
    """One concrete mlp train step: full inputs + outputs, for rust tests."""
    model = M.get("mlp")
    key = jax.random.PRNGKey(7)
    params = M.init_params(key, model)
    bn = M.init_bn(model)
    bn_state = M.init_bn_state(model)
    vel = T.init_velocities(params)
    vbn = T.init_velocities(bn)
    rs = M.init_projections(key, model)
    wps = M.project_all(model, params, rs)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (model.batch,) + model.input_shape, jnp.float32)
    y = jax.random.randint(ky, (model.batch,), 0, model.n_classes)
    gamma = jnp.float32(0.5)
    lr = jnp.float32(0.05)
    step = jnp.int32(0)

    args = (params, vel, bn, vbn, bn_state, wps, rs, x, y, gamma, lr, step)
    outs = jax.jit(T.make_train_step(model))(*args)

    flat_in, _ = jax.tree_util.tree_flatten(args)
    flat_out, _ = jax.tree_util.tree_flatten(outs)
    tensors = [(f"in{i}", np.asarray(a)) for i, a in enumerate(flat_in)]
    tensors += [(f"out{i}", np.asarray(a)) for i, a in enumerate(flat_out)]
    _write_golden(os.path.join(out_dir, "golden", "mlp_step"), tensors)
    print(f"  golden/mlp_step: {len(flat_in)} in, {len(flat_out)} out")


def export_kernel_artifacts(out_dir: str):
    """Standalone L1 kernel HLO + golden: the runtime smoke path."""
    kdir = os.path.join(out_dir, "kernels")
    os.makedirs(kdir, exist_ok=True)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((32, 96), dtype=np.float32)
    w = rng.standard_normal((96, 64), dtype=np.float32)
    mask = (rng.random((32, 64)) < 0.5).astype(np.float32)

    fn = lambda x, w, m: mm.masked_matmul(x, w, m)
    lower_to_file(
        fn,
        (
            jax.ShapeDtypeStruct((32, 96), jnp.float32),
            jax.ShapeDtypeStruct((96, 64), jnp.float32),
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
        ),
        os.path.join(kdir, "masked_matmul.hlo.txt"),
    )
    out = np.asarray(jax.jit(fn)(x, w, mask))
    _write_golden(
        os.path.join(kdir, "masked_matmul"),
        [("x", x), ("w", w), ("mask", mask), ("out", out)],
    )
    print("  kernels/masked_matmul: artifact + golden")


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--fast", action="store_true", help="mlp+lenet only")
    ap.add_argument("--only", default=None, help="export a single variant")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    variants = build_variants(args.fast)
    if args.only:
        variants = [v for v in variants if v[0] == args.only]
        if not variants:
            sys.exit(f"no variant named {args.only!r}")

    t0 = time.time()
    index = {}
    for name, model, probe in variants:
        meta = export_variant(name, model, out_dir, probe)
        index[name] = f"{name}.meta.json"
    export_golden_mlp(out_dir)
    export_kernel_artifacts(out_dir)
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"exported {len(variants)} variants in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
