"""Sparse-random-projection Pallas kernels (paper §2.2, eq. 5-6).

``project``          : Xp = X @ R.T / sqrt(k)   (per-sample projection)
``project_weights``  : Wp = R @ W / sqrt(k)     (refreshed every 50 steps
                                                 by the rust coordinator)

R is the Achlioptas ternary matrix with entries {-sqrt(s), 0, +sqrt(s)},
P(+-) = 1/(2s), s = 3 (67% zeros).  On real hardware the ternary structure
removes all multiplies; in the HLO/MXU world we keep R dense f32 — the
win that survives is the d -> k (~8.5x at eps=0.5) shrink of the inner
dimension, which is exactly the paper's low-dimensional-search saving.

The 1/sqrt(k) scale is fused into the final K-step epilogue so the
projected tile leaves VMEM already normalized.

Both entry points have a custom_vjp: the DRS estimate sits behind
stop_gradient in the model, but jax still JVP-traces through it while
building the backward graph, and pallas kernels that branch on
``pl.program_id`` are not JVP-traceable.  The vjp is mathematically the
transpose projection (it is DCE'd out of the exported HLO).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import pick_block

# TPU-target tile sizes (the BlockSpec the MXU schedule would use; these
# drive the VMEM/MXU estimates in EXPERIMENTS.md §Perf):
TPU_BM, TPU_BN, TPU_BK = 128, 128, 256
# Interpret-mode execution pays a fixed cost PER GRID STEP (dynamic-slice
# + interpreter dispatch, ~5ms); on CPU we therefore run each kernel as a
# single full-array block.  pick_block clamps to the actual dims.
_BM = _BN = _BK = 1 << 30


def _scaled_matmul_kernel(a_ref, b_ref, o_ref, *, nk: int, scale: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] *= jnp.float32(scale)


def scaled_matmul_impl(a, b, scale, bm: int = _BM, bn: int = _BN, bk: int = _BK):
    """``(a @ b) * scale`` as a tiled Pallas kernel (no vjp)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_scaled_matmul_kernel, nk=grid[2], scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def project(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """f(X) = X R^T / sqrt(k).  x: (m, d), r: (k, d) -> (m, k)."""
    k = r.shape[0]
    assert x.shape[1] == r.shape[1], (
        f"projection dim mismatch: x d={x.shape[1]} r d={r.shape[1]}"
    )
    return scaled_matmul_impl(x, r.T, 1.0 / float(k) ** 0.5)


def _project_fwd(x, r):
    k = r.shape[0]
    return scaled_matmul_impl(x, r.T, 1.0 / float(k) ** 0.5), (x, r)


def _project_bwd(res, g):
    x, r = res
    k = r.shape[0]
    gx = scaled_matmul_impl(g, r, 1.0 / float(k) ** 0.5)
    gr = scaled_matmul_impl(g.T, x, 1.0 / float(k) ** 0.5)
    return gx, gr


project.defvjp(_project_fwd, _project_bwd)


@jax.custom_vjp
def project_weights(r: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f(W) = R W / sqrt(k).  r: (k, d), w: (d, n) -> (k, n)."""
    k = r.shape[0]
    assert r.shape[1] == w.shape[0], (
        f"projection dim mismatch: r d={r.shape[1]} w d={w.shape[0]}"
    )
    return scaled_matmul_impl(r, w, 1.0 / float(k) ** 0.5)


def _project_weights_fwd(r, w):
    k = r.shape[0]
    return scaled_matmul_impl(r, w, 1.0 / float(k) ** 0.5), (r, w)


def _project_weights_bwd(res, g):
    r, w = res
    k = r.shape[0]
    gr = scaled_matmul_impl(g, w.T, 1.0 / float(k) ** 0.5)
    gw = scaled_matmul_impl(r.T, g, 1.0 / float(k) ** 0.5)
    return gr, gw


project_weights.defvjp(_project_weights_fwd, _project_weights_bwd)
