"""L1 — Pallas kernels for the DSG hot spots.

All kernels are lowered with ``interpret=True`` so they become plain HLO
ops that the CPU PJRT client (rust side, xla_extension 0.5.1) can run.
Real-TPU performance is *estimated* from the BlockSpec arithmetic in
DESIGN.md / EXPERIMENTS.md §Perf; on TPU the same kernels would lower to
Mosaic custom-calls.

Kernels
-------
- ``projection.project``          — sparse random projection  Xp = X R^T / sqrt(k)
- ``projection.project_weights``  — Wp = R W / sqrt(k)
- ``masked_matmul.masked_matmul`` — Y = (X W) * M with mask epilogue
- ``masked_matmul.matmul``        — plain tiled matmul (baseline path)
- ``topk_mask.threshold_mask``    — M = (V >= t); ``apply`` fuses Y * M

``ref.py`` holds the pure-jnp oracles used by pytest/hypothesis.
"""

from . import masked_matmul, projection, ref, topk_mask  # noqa: F401

__all__ = ["projection", "topk_mask", "masked_matmul", "ref"]
