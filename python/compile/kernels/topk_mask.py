"""Threshold-compare / mask-apply Pallas kernels (paper §2.1, Fig 9).

The DSG selection mask is ``M = (V >= t)`` where V are the virtual
activations estimated in the low-dimensional space and ``t`` is the top-k
threshold searched on the *first* sample of the mini-batch and shared
across the batch (inter-sample threshold sharing, Appendix B).

These are VPU (elementwise) kernels, not MXU work: on TPU they stream the
activation tile once, fusing compare + select + multiply.  Two entry
points:

- ``threshold_mask(virt, t)``     -> binary mask, same shape as virt
- ``threshold_apply(y, virt, t)`` -> y * (virt >= t)   (fused single pass)

The threshold itself comes from a full sort at L2 (``jnp.sort`` lowers to
an XLA sort) indexed by a *runtime* gamma index, so one artifact serves
every sparsity level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import pick_block

# TPU-target tile sizes (VPU lanes); interpret mode uses one block — see
# masked_matmul.py for the per-grid-step cost rationale.
TPU_BM, TPU_BN = 256, 256
_BM = _BN = 1 << 30


def _mask_kernel(v_ref, t_ref, o_ref):
    o_ref[...] = (v_ref[...] >= t_ref[0, 0]).astype(o_ref.dtype)


def _apply_kernel(y_ref, v_ref, t_ref, o_ref):
    o_ref[...] = y_ref[...] * (v_ref[...] >= t_ref[0, 0]).astype(y_ref.dtype)


def _block_2d(x: jnp.ndarray):
    """View any tensor as 2-D (rows, cols) for elementwise tiling."""
    if x.ndim == 1:
        return x.reshape(1, -1), x.shape
    if x.ndim == 2:
        return x, x.shape
    lead = 1
    for d in x.shape[:-1]:
        lead *= d
    return x.reshape(lead, x.shape[-1]), x.shape


def threshold_mask_impl(virt, thresh, bm: int = _BM, bn: int = _BN):
    """Binary selection mask: 1.0 where ``virt >= thresh`` (no vjp)."""
    v2, orig = _block_2d(virt)
    m, n = v2.shape
    bm, bn = pick_block(m, bm), pick_block(n, bn)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(v2.astype(jnp.float32), t)
    return out.reshape(orig)


def threshold_apply_impl(y, virt, thresh, bm: int = _BM, bn: int = _BN):
    """Fused mask apply ``y * (virt >= thresh)`` (no vjp)."""
    assert y.shape == virt.shape, f"{y.shape} != {virt.shape}"
    y2, orig = _block_2d(y)
    v2, _ = _block_2d(virt)
    m, n = y2.shape
    bm, bn = pick_block(m, bm), pick_block(n, bn)
    t = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _apply_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(y2.astype(jnp.float32), v2.astype(jnp.float32), t)
    return out.reshape(orig)


# ---------------------------------------------------------------------------
# Differentiable entry points (custom_vjp: pallas JVP tracing is
# unavailable, and Algorithm 1 *specifies* the backward masking anyway)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def threshold_mask(virt: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Binary selection mask: 1.0 where ``virt >= thresh`` else 0.0.

    Non-differentiable (piecewise-constant): the vjp is zero, matching the
    paper's treatment of the mask as a constant during backprop.
    """
    return threshold_mask_impl(virt, thresh)


def _mask_fwd(virt, thresh):
    return threshold_mask_impl(virt, thresh), (virt.shape, virt.dtype)


def _mask_bwd(res, g):
    shape, dtype = res
    return jnp.zeros(shape, dtype), jnp.zeros((), jnp.float32)


threshold_mask.defvjp(_mask_fwd, _mask_bwd)


@jax.custom_vjp
def threshold_apply(
    y: jnp.ndarray, virt: jnp.ndarray, thresh: jnp.ndarray
) -> jnp.ndarray:
    """Fused mask apply: ``y * (virt >= thresh)`` in a single pass.

    Backward (Algorithm 1): the upstream gradient passes through the SAME
    mask — ``gy = g * (virt >= t)`` — computed by the same fused kernel,
    i.e. gradients are forcibly sparsified at every mask layer.
    """
    return threshold_apply_impl(y, virt, thresh)


def _apply_fwd(y, virt, thresh):
    t = jnp.asarray(thresh, jnp.float32)
    return threshold_apply_impl(y, virt, t), (virt, t)


def _apply_bwd(res, g):
    virt, t = res
    gy = threshold_apply_impl(g, virt, t)  # backward masking
    return gy, jnp.zeros_like(virt), jnp.zeros((), jnp.float32)


threshold_apply.defvjp(_apply_fwd, _apply_bwd)
