"""Tiled (masked) matmul Pallas kernels.

The DSG exact-compute hot spot: ``Y = (X @ W) * M`` where ``M`` is the
binary selection mask produced by the dimension-reduction search.  On TPU
the mask-multiply is an epilogue fused into the final K-step of the MXU
matmul tile, so the masked output never round-trips to HBM dense.

Grid is (M/bm, N/bn, K/bk) with sequential K accumulation into the output
block — the canonical Pallas matmul schedule.  ``interpret=True``
throughout (CPU PJRT cannot execute Mosaic custom-calls).

Both entry points carry a ``custom_vjp``:

- pallas_call's automatic JVP cannot differentiate kernels that branch on
  ``pl.program_id`` (the K-step init/epilogue), and
- the paper's Algorithm 1 *defines* the backward pass explicitly: the
  upstream gradient is masked (``G * M``) and then flows through two more
  matmuls — so the backward is itself built from these same kernels,
  giving the forced gradient sparsification for free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import pick_block

# Preferred block sizes: MXU-native 128x128 output tiles, 256-deep K
# panels (f32: 3 tiles * 128*256*4B = 384 KiB << VMEM budget).
# TPU-target tile sizes (the BlockSpec the MXU schedule would use; these
# drive the VMEM/MXU estimates in EXPERIMENTS.md §Perf):
TPU_BM, TPU_BN, TPU_BK = 128, 128, 256
# Interpret-mode execution pays a fixed cost PER GRID STEP (dynamic-slice
# + interpreter dispatch, ~5ms); on CPU we therefore run each kernel as a
# single full-array block.  pick_block clamps to the actual dims.
_BM = _BN = _BK = 1 << 30


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Accumulating matmul tile; zero-init on the first K step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref, *, nk: int):
    """Matmul tile with mask epilogue on the last K step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] *= m_ref[...]


def _grid_and_specs(m: int, k: int, n: int, bm: int, bn: int, bk: int):
    grid = (m // bm, n // bn, k // bk)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, s: (i, s))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, s: (s, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))
    return grid, x_spec, w_spec, o_spec


def matmul_impl(x, w, bm: int = _BM, bn: int = _BN, bk: int = _BK):
    """Tiled Pallas matmul ``x @ w`` with explicit block sizes (no vjp)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    grid, x_spec, w_spec, o_spec = _grid_and_specs(m, k, n, bm, bn, bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[x_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def masked_matmul_impl(x, w, mask, bm: int = _BM, bn: int = _BN, bk: int = _BK):
    """Masked Pallas matmul ``(x @ w) * mask`` with explicit blocks (no vjp)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert mask.shape == (m, n), f"mask shape {mask.shape} != {(m, n)}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    grid, x_spec, w_spec, o_spec = _grid_and_specs(m, k, n, bm, bn, bk)
    m_spec = pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))
    return pl.pallas_call(
        functools.partial(_masked_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[x_spec, w_spec, m_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, mask.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Differentiable entry points
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Tiled Pallas matmul ``x @ w`` (differentiable)."""
    return matmul_impl(x, w)


def _matmul_fwd(x, w):
    return matmul_impl(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    gx = matmul_impl(g, w.T)
    gw = matmul_impl(x.T, g)
    return gx, gw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@jax.custom_vjp
def masked_matmul(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """DSG structured-sparse matmul: ``(x @ w) * mask``.

    ``mask`` is (m, n) binary — each row selects which output neurons
    (columns of ``w``) this input row actually computes.  Numerically
    exact w.r.t. the dense product; the wall-clock skip lives in the rust
    engine (`rust/src/sparse/`), and on TPU in the HBM->VMEM schedule.

    Backward (Algorithm 1): the upstream gradient is masked first, then
    ``gx = (g*M) W^T`` and ``gw = X^T (g*M)`` — both tiled Pallas matmuls,
    so the backward pass is exactly as sparse as the forward.
    """
    return masked_matmul_impl(x, w, mask)


def _masked_matmul_fwd(x, w, mask):
    return masked_matmul_impl(x, w, mask), (x, w, mask)


def _masked_matmul_bwd(res, g):
    x, w, mask = res
    gm = g * mask  # forced gradient sparsification at the mask layer
    gx = matmul_impl(gm, w.T)
    gw = matmul_impl(x.T, gm)
    return gx, gw, jnp.zeros_like(mask)


masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)
