"""Pure-jnp oracles for every L1 Pallas kernel.

These are the single source of numerical truth: pytest/hypothesis sweeps
assert the Pallas kernels match these to float tolerance, and the rust
integration tests check the loaded HLO against values produced by these
(via golden files emitted at `make artifacts` time).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle: ``x @ w`` in f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def project(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Sparse-random-projection oracle (paper eq. 5).

    x: (m, d), r: (k, d) ternary in {-sqrt(s), 0, +sqrt(s)}.
    Returns f(x) = x @ r.T / sqrt(k) with shape (m, k).
    """
    k = r.shape[0]
    return jnp.dot(x, r.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(k)
    )


def project_weights(r: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Weight-side projection oracle: f(w_j) = R w_j / sqrt(k), all j.

    r: (k, d), w: (d, n) -> (k, n).
    """
    k = r.shape[0]
    return jnp.dot(r, w, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(k)
    )


def threshold_mask(virt: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Binary selection mask oracle: 1 where virt >= thresh (paper Fig 9)."""
    return (virt >= thresh).astype(virt.dtype)


def threshold_apply(y: jnp.ndarray, virt: jnp.ndarray, thresh) -> jnp.ndarray:
    """Fused mask-apply oracle: y * (virt >= thresh)."""
    return y * threshold_mask(virt, jnp.asarray(thresh, virt.dtype))


def masked_matmul(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Structured-sparse matmul oracle: (x @ w) * mask.

    mask: (m, n) binary — the paper's vector-wise selection; columns of w
    whose mask entries are zero are never needed (the rust engine really
    skips them; here the multiply is the numerically-exact equivalent).
    """
    return jnp.dot(x, w, preferred_element_type=jnp.float32) * mask


def topk_threshold(virt_row: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Top-k threshold oracle over one flattened sample (threshold sharing).

    Returns the ``keep``-th largest value of ``virt_row`` (keep >= 1); the
    mini-batch shares this threshold (paper Appendix B, Fig 9).
    """
    flat = virt_row.reshape(-1)
    sorted_desc = jnp.sort(flat)[::-1]
    idx = jnp.clip(keep - 1, 0, flat.shape[0] - 1)
    return sorted_desc[idx]
