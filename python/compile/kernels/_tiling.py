"""Shared tiling helpers for the Pallas kernels.

TPU-adaptation notes (see DESIGN.md §Hardware adaptation): block shapes
are chosen to fit VMEM (~16 MiB/core budget, we target <= 4 MiB per
operand tile) and to keep the MXU fed with (128, 128) f32 / (128, 256)
bf16 tiles.  On CPU (interpret mode) the same shapes simply bound the
working set; correctness is tiling-invariant and pytest sweeps odd shapes.
"""

from __future__ import annotations

import jax.numpy as jnp


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= ``preferred``.

    Guarantees an exact grid (no ragged edge) so kernels never read
    out-of-bounds; callers pad to a friendly multiple first when they
    care about block quality.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def pad_to_multiple(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to the next multiple."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def vmem_bytes(block_shape, dtype=jnp.float32) -> int:
    """Estimated VMEM bytes for one operand tile (perf model input)."""
    n = 1
    for d in block_shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize
