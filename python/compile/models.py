"""L2 — model zoo (paper §3.1) and the functional forward pass.

Models (sized for the CPU testbed; the paper's exact widths are kept as
"full" variants used by the analytic cost models in rust/src/costmodel):

  mlp        784-256-256-10 MLP                     (FASHION-like)
  lenet      LeNet-5                                (FASHION-like)
  vgg8       2x(wC3)-MP2-2x(2wC3)-MP2-2x(4wC3)-MP2-8wFC-10  (CIFAR-like)
             paper width w=128; default lite w=32
  resnet8    conv + 3 residual blocks + 2 FC        (paper's custom variant)
  wrn8_2     resnet8 with 2x width                  (WRN-8-2)

Every conv/dense (except the classifier and residual shortcuts) is a DSG
layer: dimension-reduction search -> shared threshold -> double-mask BN.

Parameters, BN state, projected weights (Wp) and projection matrices (R)
are *flat ordered lists* so the rust coordinator can thread buffers
positionally; `aot.py` records the layout in the artifact meta JSON.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import layers as L

Unit = Union[L.Dense, L.Conv, L.MaxPool, L.GlobalAvgPool, L.Flatten, L.Residual]


@dataclasses.dataclass(frozen=True)
class Model:
    name: str
    input_shape: Tuple[int, ...]  # (C,H,W) for conv nets, (D,) for MLP
    n_classes: int
    batch: int
    units: Tuple[Unit, ...]
    opts: L.DSGOptions = L.DSGOptions()

    def with_opts(self, **kw) -> "Model":
        return dataclasses.replace(
            self, opts=dataclasses.replace(self.opts, **kw)
        )

    def renamed(self, name: str) -> "Model":
        return dataclasses.replace(self, name=name)


# ---------------------------------------------------------------------------
# Zoo
# ---------------------------------------------------------------------------


def mlp(batch: int = 64, hidden: int = 256) -> Model:
    return Model(
        name="mlp",
        input_shape=(784,),
        n_classes=10,
        batch=batch,
        units=(
            L.Dense(784, hidden),
            L.Dense(hidden, hidden),
            L.Dense(hidden, 10, classifier=True),
        ),
    )


def lenet(batch: int = 32) -> Model:
    return Model(
        name="lenet",
        input_shape=(1, 28, 28),
        n_classes=10,
        batch=batch,
        units=(
            L.Conv(1, 6, ksize=5, pad=2),
            L.MaxPool(),
            L.Conv(6, 16, ksize=5, pad=0),
            L.MaxPool(),
            L.Flatten(),
            L.Dense(16 * 5 * 5, 120),
            L.Dense(120, 84),
            L.Dense(84, 10, classifier=True),
        ),
    )


def vgg8(batch: int = 16, width: int = 32, name: str = "vgg8") -> Model:
    w = width
    return Model(
        name=name,
        input_shape=(3, 32, 32),
        n_classes=10,
        batch=batch,
        units=(
            L.Conv(3, w),
            L.Conv(w, w),
            L.MaxPool(),
            L.Conv(w, 2 * w),
            L.Conv(2 * w, 2 * w),
            L.MaxPool(),
            L.Conv(2 * w, 4 * w),
            L.Conv(4 * w, 4 * w),
            L.MaxPool(),
            L.Flatten(),
            L.Dense(4 * w * 4 * 4, 8 * w),
            L.Dense(8 * w, 10, classifier=True),
        ),
    )


def resnet8(batch: int = 16, width: int = 16, name: str = "resnet8") -> Model:
    w = width
    return Model(
        name=name,
        input_shape=(3, 32, 32),
        n_classes=10,
        batch=batch,
        units=(
            L.Conv(3, w),
            L.Residual(w, w),
            L.Residual(w, 2 * w, stride=2),
            L.Residual(2 * w, 4 * w, stride=2),
            L.GlobalAvgPool(),
            L.Dense(4 * w, 64),
            L.Dense(64, 10, classifier=True),
        ),
    )


def wrn8_2(batch: int = 16) -> Model:
    return resnet8(batch=batch, width=32, name="wrn8_2")


ZOO = {
    "mlp": mlp,
    "lenet": lenet,
    "vgg8": vgg8,
    "resnet8": resnet8,
    "wrn8_2": wrn8_2,
}


def get(name: str, **kw) -> Model:
    if name not in ZOO:
        raise KeyError(f"unknown model {name!r}; have {sorted(ZOO)}")
    return ZOO[name](**kw)


# ---------------------------------------------------------------------------
# DSG layer enumeration (order defines the wp/r list layout)
# ---------------------------------------------------------------------------


def dsg_specs(model: Model) -> List[Tuple[str, Union[L.Dense, L.Conv]]]:
    """(path, spec) for every DSG-masked layer, in buffer order.

    Residual shortcuts (1x1 convs) stay dense — they are cheap relative to
    the 3x3 branch convs and masking them would couple the two branch
    masks through the addition; the paper masks the main-path layers.
    Classifier layers are never masked.
    """
    out: List[Tuple[str, Union[L.Dense, L.Conv]]] = []
    for i, u in enumerate(model.units):
        if isinstance(u, L.Dense) and not u.classifier:
            out.append((f"u{i}", u))
        elif isinstance(u, L.Conv):
            out.append((f"u{i}", u))
        elif isinstance(u, L.Residual):
            c1 = L.Conv(u.c_in, u.c_out, 3, u.stride, 1)
            c2 = L.Conv(u.c_out, u.c_out, 3, 1, 1)
            out.append((f"u{i}.conv1", c1))
            out.append((f"u{i}.conv2", c2))
    return out


def projection_shapes(model: Model) -> List[Tuple[str, int, int, int]]:
    """(path, k, d_in, n_out) per DSG layer — R is (k, d_in), Wp (k, n_out)."""
    out = []
    for path, spec in dsg_specs(model):
        k = L.projection_dim_for(spec, model.opts.eps)
        n_out = spec.d_out if isinstance(spec, L.Dense) else spec.c_out
        out.append((path, k, spec.d_in, n_out))
    return out


# ---------------------------------------------------------------------------
# Init (python mirror of rust/src/coordinator/init.rs; used by pytest)
# ---------------------------------------------------------------------------


def init_params(key, model: Model) -> List[dict]:
    params = []
    for u in model.units:
        key, sub = jax.random.split(key)
        if isinstance(u, L.Dense):
            params.append(L.init_dense(sub, u))
        elif isinstance(u, L.Conv):
            params.append(L.init_conv(sub, u))
        elif isinstance(u, L.Residual):
            k1, k2, k3 = jax.random.split(sub, 3)
            p = {
                "conv1": L.init_conv(k1, L.Conv(u.c_in, u.c_out, 3, u.stride, 1)),
                "conv2": L.init_conv(k2, L.Conv(u.c_out, u.c_out, 3, 1, 1)),
            }
            if u.stride != 1 or u.c_in != u.c_out:
                p["short"] = L.init_conv(
                    k3, L.Conv(u.c_in, u.c_out, 1, u.stride, 0)
                )
            params.append(p)
        else:
            params.append({})
    return params


def init_bn(model: Model) -> List[dict]:
    bns = []
    for u in model.units:
        if isinstance(u, L.Dense) and not u.classifier:
            bns.append(L.init_bn(u.d_out))
        elif isinstance(u, L.Conv):
            bns.append(L.init_bn(u.c_out))
        elif isinstance(u, L.Residual):
            bns.append({"bn1": L.init_bn(u.c_out), "bn2": L.init_bn(u.c_out)})
        else:
            bns.append({})
    return bns


def init_bn_state(model: Model) -> List[dict]:
    sts = []
    for u in model.units:
        if isinstance(u, L.Dense) and not u.classifier:
            sts.append(L.init_bn_state(u.d_out))
        elif isinstance(u, L.Conv):
            sts.append(L.init_bn_state(u.c_out))
        elif isinstance(u, L.Residual):
            sts.append(
                {"bn1": L.init_bn_state(u.c_out), "bn2": L.init_bn_state(u.c_out)}
            )
        else:
            sts.append({})
    return sts


def init_projections(key, model: Model, s: int = 3) -> List[jnp.ndarray]:
    """Ternary Achlioptas R per DSG layer (paper eq. 6), fixed for the run."""
    rs = []
    for _, k, d_in, _ in projection_shapes(model):
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, (k, d_in))
        val = jnp.sqrt(jnp.float32(s))
        r = jnp.where(
            u < 1.0 / (2 * s),
            -val,
            jnp.where(u < 1.0 / s, val, jnp.float32(0.0)),
        )
        rs.append(r)
    return rs


def project_all(model: Model, params: Sequence[dict], rs) -> List[jnp.ndarray]:
    """Wp for every DSG layer (the every-50-steps refresh computation)."""
    from .kernels import projection as pj

    wps = []
    idx = 0
    for i, u in enumerate(model.units):
        if isinstance(u, L.Dense) and not u.classifier:
            wps.append(pj.project_weights(rs[idx], params[i]["w"]))
            idx += 1
        elif isinstance(u, L.Conv):
            wmat = params[i]["w"].reshape(u.c_out, -1).T  # (CRS, K)
            wps.append(pj.project_weights(rs[idx], wmat))
            idx += 1
        elif isinstance(u, L.Residual):
            for sub in ("conv1", "conv2"):
                w = params[i][sub]["w"]
                wmat = w.reshape(w.shape[0], -1).T
                wps.append(pj.project_weights(rs[idx], wmat))
                idx += 1
    return wps


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(
    model: Model,
    params: Sequence[dict],
    bn: Sequence[dict],
    bn_state: Sequence[dict],
    wps: Sequence[jnp.ndarray],
    rs: Sequence[jnp.ndarray],
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    train: bool,
    step: jnp.ndarray,
    capture=None,
):
    """Run the DSG forward pass.

    Returns (logits, new_bn_state, mask_densities) where mask_densities is
    one scalar per DSG layer (feeds Fig 1f / Fig 6 measurements in rust).
    If ``capture`` is a list, the full binary selection mask of every DSG
    layer is appended to it (the Fig 11 probe artifact).
    """
    opts = model.opts
    opts.validate()
    # noise seed for the random-selection baseline: plain scalar (the
    # threefry PRNG lowers to an rng_bit_generator custom-call the old
    # xla_extension cannot run; see layers.hash_noise)
    seed_base = jnp.asarray(step, jnp.float32) * 131.0
    h = x
    new_bn_state: List[dict] = []
    densities: List[jnp.ndarray] = []
    dsg_idx = 0

    def next_proj():
        nonlocal dsg_idx
        if opts.strategy in ("drs",):
            wp, r = wps[dsg_idx], rs[dsg_idx]
        else:  # oracle / random / dense never read them
            wp, r = None, None
        i = dsg_idx
        dsg_idx += 1
        return wp, r, i

    for i, u in enumerate(model.units):
        if isinstance(u, L.Dense) and not u.classifier:
            wp, r, li = next_proj()
            h, st, stats = L.dense_forward(
                h,
                params[i],
                bn[i],
                bn_state[i],
                wp,
                r,
                gamma,
                opts,
                train,
                seed_base + li,
                capture,
            )
            new_bn_state.append(st)
            densities.append(stats["mask_density"])
        elif isinstance(u, L.Dense):
            h = L.classifier_forward(h, params[i])
            new_bn_state.append(bn_state[i])
        elif isinstance(u, L.Conv):
            wp, r, li = next_proj()
            h, st, stats = L.conv_forward(
                h,
                params[i],
                bn[i],
                bn_state[i],
                wp,
                r,
                gamma,
                u,
                opts,
                train,
                seed_base + li,
                capture,
            )
            new_bn_state.append(st)
            densities.append(stats["mask_density"])
        elif isinstance(u, L.Residual):
            c1 = L.Conv(u.c_in, u.c_out, 3, u.stride, 1)
            c2 = L.Conv(u.c_out, u.c_out, 3, 1, 1)
            wp1, r1, l1 = next_proj()
            b1, st1, s1 = L.conv_forward(
                h,
                params[i]["conv1"],
                bn[i]["bn1"],
                bn_state[i]["bn1"],
                wp1,
                r1,
                gamma,
                c1,
                opts,
                train,
                seed_base + l1,
                capture,
            )
            wp2, r2, l2 = next_proj()
            b2, st2, s2 = L.conv_forward(
                b1,
                params[i]["conv2"],
                bn[i]["bn2"],
                bn_state[i]["bn2"],
                wp2,
                r2,
                gamma,
                c2,
                opts,
                train,
                seed_base + l2,
                capture,
            )
            if "short" in params[i]:
                sc = L._conv(h, params[i]["short"]["w"], u.stride, 0)
            else:
                sc = h
            h = b2 + sc
            new_bn_state.append({"bn1": st1, "bn2": st2})
            densities.append(s1["mask_density"])
            densities.append(s2["mask_density"])
        elif isinstance(u, L.MaxPool):
            h = L.maxpool(h, u.size)
            new_bn_state.append(bn_state[i])
        elif isinstance(u, L.GlobalAvgPool):
            h = L.global_avg_pool(h)
            new_bn_state.append(bn_state[i])
        elif isinstance(u, L.Flatten):
            h = h.reshape(h.shape[0], -1)
            new_bn_state.append(bn_state[i])
        else:
            raise TypeError(f"unknown unit {u}")
    return h, new_bn_state, densities
