"""L2 — DSG layers: conv / dense with dimension-reduction search,
double-mask BatchNorm, and selection-strategy baselines.

Layer dataflow (paper Algorithm 1, §2.3; order CONV/FC -> ReLU -> BN):

    virt   = DRS estimate of pre-activations (low-dim Pallas matmul)
    t      = top-k threshold from sample 0 (inter-sample sharing, Fig 9)
    mask   = virt >= t                                  [stop-gradient]
    s      = relu( (x (*) W) * mask )                   [mask 1]
    out    = BN(s) * mask                               [mask 2]

Selection strategies (Fig 5c):
    'drs'    — virtual activations from the random projection (the paper)
    'oracle' — virtual activations = exact pre-activations (upper bound)
    'random' — virtual activations = fresh Gaussian noise (lower bound)
    'dense'  — no masking at all (gamma ignored)

The sparsity level gamma is a *runtime* scalar: the threshold indexes a
full sort of sample-0's virtual activations with a dynamic index, so a
single HLO artifact serves every sparsity level (and lets the rust
coordinator schedule sparsity over training).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import jll
from .kernels import masked_matmul as mm
from .kernels import projection as pj
from .kernels import topk_mask as tk

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DSGOptions:
    """Static per-model DSG configuration (baked into the artifact)."""

    eps: float = 0.5  # JLL approximation knob (Fig 5d)
    strategy: str = "drs"  # drs | oracle | random | dense
    double_mask: bool = True  # False => single mask (Fig 5e case 2)
    use_bn: bool = True  # False => no BN       (Fig 5e case 1)

    def validate(self) -> None:
        if self.strategy not in ("drs", "oracle", "random", "dense"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if not (0.0 < self.eps < 1.0):
            raise ValueError(f"eps out of range: {self.eps}")


@dataclasses.dataclass(frozen=True)
class Dense:
    """FC layer. DSG-masked unless ``classifier`` (last layer, has bias)."""

    d_in: int
    d_out: int
    classifier: bool = False

    @property
    def name(self) -> str:
        return f"dense{self.d_in}x{self.d_out}"


@dataclasses.dataclass(frozen=True)
class Conv:
    """3x3/5x5 conv, stride/pad, DSG-masked, no bias (BN provides beta)."""

    c_in: int
    c_out: int
    ksize: int = 3
    stride: int = 1
    pad: int = 1

    @property
    def name(self) -> str:
        return f"conv{self.c_in}x{self.c_out}k{self.ksize}"

    @property
    def d_in(self) -> int:  # n_CRS
        return self.c_in * self.ksize * self.ksize


@dataclasses.dataclass(frozen=True)
class MaxPool:
    size: int = 2


@dataclasses.dataclass(frozen=True)
class GlobalAvgPool:
    pass


@dataclasses.dataclass(frozen=True)
class Flatten:
    pass


@dataclasses.dataclass(frozen=True)
class Residual:
    """Basic residual block of two DSG convs (+1x1 projection shortcut)."""

    c_in: int
    c_out: int
    stride: int = 1

    @property
    def name(self) -> str:
        return f"res{self.c_in}x{self.c_out}s{self.stride}"


# ---------------------------------------------------------------------------
# Parameter initialization (mirrored by rust/src/coordinator/init.rs)
# ---------------------------------------------------------------------------


def he_std(fan_in: int) -> float:
    return math.sqrt(2.0 / fan_in)


def init_dense(key, spec: Dense):
    wkey, _ = jax.random.split(key)
    w = jax.random.normal(wkey, (spec.d_in, spec.d_out), jnp.float32) * he_std(
        spec.d_in
    )
    p = {"w": w}
    if spec.classifier:
        p["b"] = jnp.zeros((spec.d_out,), jnp.float32)
    return p


def init_conv(key, spec: Conv):
    w = jax.random.normal(
        key, (spec.c_out, spec.c_in, spec.ksize, spec.ksize), jnp.float32
    ) * he_std(spec.d_in)
    return {"w": w}


def init_bn(c: int):
    return {
        "scale": jnp.ones((c,), jnp.float32),
        "bias": jnp.zeros((c,), jnp.float32),
    }


def init_bn_state(c: int):
    return {
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# BatchNorm (functional, running-stat threading)
# ---------------------------------------------------------------------------


def batchnorm(x, bn, state, train: bool, axes):
    """BN over ``axes``; returns (y, new_state). Channel dim is the one
    not reduced (dim 1 for NCHW conv, dim 1 for (N,F) dense)."""
    if train:
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    shape = [1] * x.ndim
    ch_dim = 1 if x.ndim == 4 else x.ndim - 1
    shape[ch_dim] = x.shape[ch_dim]

    def rs(v):
        return v.reshape(shape)

    y = (x - rs(mean)) * lax.rsqrt(rs(var) + BN_EPS)
    return y * rs(bn["scale"]) + rs(bn["bias"]), new_state


# ---------------------------------------------------------------------------
# DRS: threshold + masks
# ---------------------------------------------------------------------------


def shared_threshold(virt: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Inter-sample-shared top-k threshold (Appendix B, Fig 9).

    virt: (batch, ...) virtual activations.  The threshold is the value at
    rank floor(gamma * size) of sample 0's *ascending* sort, i.e. we keep
    the top ceil((1-gamma) * size) entries.  gamma is a runtime scalar.
    """
    flat0 = virt[0].reshape(-1)
    size = flat0.shape[0]
    sorted_asc = jnp.sort(flat0)
    drop = jnp.clip(
        jnp.floor(gamma * size).astype(jnp.int32), 0, size - 1
    )
    t = lax.dynamic_index_in_dim(sorted_asc, drop, keepdims=False)
    # gamma == 0 must keep EVERY neuron of EVERY sample; sample-0's min
    # would still clip other samples, so the threshold drops to -inf.
    return jnp.where(drop == 0, -jnp.inf, t)


def hash_noise(shape, seed):
    """Pseudo-random noise from a sin-hash over element index + seed.

    jax.random's threefry lowers to an ``rng_bit_generator`` custom-call
    that xla_extension 0.5.1 cannot execute (it throws a foreign C++
    exception through PJRT), so the random-selection baseline uses this
    plain-HLO counter hash instead.  Statistical quality is irrelevant
    here — it only needs to be input-independent (Fig 5c's lower bound).
    """
    n = 1
    for d in shape:
        n *= d
    idx = jnp.arange(n, dtype=jnp.float32)
    s = jnp.asarray(seed, jnp.float32)
    v = jnp.sin(idx * 12.9898 + s * 78.233) * 43758.5453
    return (v - jnp.floor(v)).reshape(shape) - 0.5


def _virtual_acts_dense(x, wp, r, w, strategy, noise_seed):
    """Virtual pre-activations for a dense layer under each strategy."""
    if strategy == "oracle":
        return mm.matmul(x, w)
    if strategy == "random":
        return hash_noise((x.shape[0], w.shape[1]), noise_seed)
    # drs: project x into k dims (Pallas), then low-dim VMM (Pallas).
    xp = pj.project(x, r)
    return mm.matmul(xp, wp)


def dense_forward(
    x,
    p,
    bn,
    bn_state,
    wp,
    r,
    gamma,
    opts: DSGOptions,
    train: bool,
    noise_key,
    capture: Optional[list] = None,
):
    """DSG dense layer: x (N, d_in) -> (out (N, d_out), new_bn_state, stats)."""
    if opts.strategy == "dense":
        y = mm.matmul(x, p["w"])
        s = jax.nn.relu(y)
        if opts.use_bn:
            out, new_state = batchnorm(s, bn, bn_state, train, axes=(0,))
        else:
            out, new_state = s, bn_state
        return out, new_state, {"mask_density": jnp.float32(1.0)}

    virt = lax.stop_gradient(
        _virtual_acts_dense(x, wp, r, p["w"], opts.strategy, noise_key)
    )
    t = lax.stop_gradient(shared_threshold(virt, gamma))
    # Mask 1 fused into the exact matmul epilogue (Pallas masked matmul).
    mask = lax.stop_gradient(tk.threshold_mask(virt, t))
    if capture is not None:
        capture.append(mask)
    y = mm.masked_matmul(x, p["w"], mask)
    s = jax.nn.relu(y)
    if opts.use_bn:
        bn_out, new_state = batchnorm(s, bn, bn_state, train, axes=(0,))
        if opts.double_mask:
            out = tk.threshold_apply(bn_out, virt, t)  # mask 2 (fused)
        else:
            out = bn_out
    else:
        out, new_state = s, bn_state
    return out, new_state, {"mask_density": jnp.mean(mask)}


def _conv(x, w, stride, pad):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _virtual_acts_conv(x, wp, r, spec: Conv, w, strategy, noise_seed, out_hw):
    """Virtual pre-activations (N, K, P, Q) for a conv layer.

    DRS path: project every sliding window with a conv whose kernel is the
    ternary R reshaped (k, C, r, s) — identical math to projecting each
    im2col row — then run the low-dimensional VMM as a Pallas matmul
    against the projected weight matrix Wp (k, K).
    """
    n = x.shape[0]
    p_, q_ = out_hw
    if strategy == "oracle":
        return _conv(x, w, spec.stride, spec.pad)
    if strategy == "random":
        return hash_noise((n, spec.c_out, p_, q_), noise_seed)
    k = r.shape[0]
    r_kernel = r.reshape(k, spec.c_in, spec.ksize, spec.ksize)
    xp = _conv(x, r_kernel, spec.stride, spec.pad) / jnp.sqrt(jnp.float32(k))
    # (N, k, P, Q) -> (N*P*Q, k) @ (k, K) -> (N, K, P, Q)
    xp2 = xp.transpose(0, 2, 3, 1).reshape(n * p_ * q_, k)
    virt = mm.matmul(xp2, wp)
    return virt.reshape(n, p_, q_, spec.c_out).transpose(0, 3, 1, 2)


def conv_forward(
    x,
    p,
    bn,
    bn_state,
    wp,
    r,
    gamma,
    spec: Conv,
    opts: DSGOptions,
    train: bool,
    noise_key,
    capture: Optional[list] = None,
):
    """DSG conv layer: x (N,C,H,W) -> (out (N,K,P,Q), new_bn_state, stats)."""
    if opts.strategy == "dense":
        y = _conv(x, p["w"], spec.stride, spec.pad)
        s = jax.nn.relu(y)
        if opts.use_bn:
            out, new_state = batchnorm(s, bn, bn_state, train, axes=(0, 2, 3))
        else:
            out, new_state = s, bn_state
        return out, new_state, {"mask_density": jnp.float32(1.0)}

    y = _conv(x, p["w"], spec.stride, spec.pad)
    out_hw = (y.shape[2], y.shape[3])
    virt = lax.stop_gradient(
        _virtual_acts_conv(
            x, wp, r, spec, p["w"], opts.strategy, noise_key, out_hw
        )
    )
    t = lax.stop_gradient(shared_threshold(virt, gamma))
    if capture is not None:
        capture.append(tk.threshold_mask(virt, t))
    s = jax.nn.relu(tk.threshold_apply(y, virt, t))  # mask 1 (fused)
    if opts.use_bn:
        bn_out, new_state = batchnorm(s, bn, bn_state, train, axes=(0, 2, 3))
        if opts.double_mask:
            out = tk.threshold_apply(bn_out, virt, t)  # mask 2
        else:
            out = bn_out
    else:
        out, new_state = s, bn_state
    density = jnp.mean((virt >= t).astype(jnp.float32))
    return out, new_state, {"mask_density": density}


def classifier_forward(x, p):
    """Final un-masked, un-normalized linear layer (logits)."""
    return mm.matmul(x, p["w"]) + p["b"]


def projection_dim_for(spec, eps: float) -> int:
    """k for a layer spec (shared JLL model)."""
    if isinstance(spec, Dense):
        return jll.projection_dim(eps, spec.d_out, spec.d_in)
    if isinstance(spec, Conv):
        return jll.projection_dim(eps, spec.c_out, spec.d_in)
    raise TypeError(f"no projection for {spec}")


def maxpool(x, size: int):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, 1, size, size),
        (1, 1, size, size),
        "VALID",
    )


def global_avg_pool(x):
    return jnp.mean(x, axis=(2, 3))
