"""DSG semantic invariants beyond per-kernel correctness: the claims the
paper's method rests on, checked directly on the L2 graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L
from compile import models as M
from compile import train as T
from compile.kernels import projection as pj
from compile.kernels import ref


def _ternary(rng, k, d, s=3):
    u = rng.random((k, d))
    r = np.zeros((k, d), dtype=np.float32)
    r[u < 1 / (2 * s)] = -np.sqrt(s)
    r[(u >= 1 / (2 * s)) & (u < 1 / s)] = np.sqrt(s)
    return jnp.asarray(r)


# ---------------------------------------------------------------------------
# DRS ranking quality: the reason dimension-reduction search works
# ---------------------------------------------------------------------------


def test_drs_ranking_overlaps_oracle(rng):
    """The top-k set selected in the projected space must substantially
    overlap the true top-k set when activations have structure (real
    layers are heavy-tailed; iid-Gaussian outputs carry no top-k signal
    for ANY eps-accurate estimator, so we scale a third of the neurons)."""
    d, n, k = 1152, 128, 232  # conv3-ish at eps 0.5
    x = jnp.asarray(rng.standard_normal((1, d), dtype=np.float32))
    w_np = rng.standard_normal((d, n)).astype(np.float32) / np.sqrt(d)
    w_np[:, : n // 3] *= 3.0  # structured spread, like trained filters
    w = jnp.asarray(w_np)
    r = _ternary(rng, k, d)
    true_acts = np.asarray(ref.matmul(x, w))[0]
    xp = pj.project(x, r)
    wp = ref.project_weights(r, w)
    virt = np.asarray(ref.matmul(xp, wp))[0]
    keep = n // 5  # gamma = 0.8
    drs_top = set(np.argsort(virt)[-keep:].tolist())
    # The property that matters for accuracy (App. A): every selected
    # neuron has a LARGE true activation, i.e. falls within the true
    # top-2k — exact rank order within the near-top is noise at eps 0.5.
    near_top = set(np.argsort(true_acts)[-2 * keep :].tolist())
    precision = len(drs_top & near_top) / keep
    chance = 2 * keep / n  # random selection's expected precision
    assert precision > chance + 0.2, (
        f"DRS near-top precision {precision:.2f} barely above chance {chance:.2f}"
    )
    # and strictly better than chance at hitting the exact top-k
    true_top = set(np.argsort(true_acts)[-keep:].tolist())
    overlap = len(true_top & drs_top) / keep
    assert overlap > 2 * keep / n, f"overlap {overlap:.2f} not above chance"


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_drs_ranking_beats_random(seed):
    rng = np.random.default_rng(seed)
    d, n, k = 512, 64, 180
    x = jnp.asarray(rng.standard_normal((1, d), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((d, n), dtype=np.float32) / np.sqrt(d))
    r = _ternary(rng, k, d)
    true_acts = np.asarray(ref.matmul(x, w))[0]
    virt = np.asarray(
        ref.matmul(pj.project(x, r), ref.project_weights(r, w))
    )[0]
    keep = n // 4
    true_top = set(np.argsort(true_acts)[-keep:])
    drs_top = set(np.argsort(virt)[-keep:])
    rand_top = set(rng.choice(n, keep, replace=False).tolist())
    assert len(true_top & drs_top) >= len(true_top & rand_top)


# ---------------------------------------------------------------------------
# BN damage + double-mask recovery (Fig 1e / Fig 2c) on the live graph
# ---------------------------------------------------------------------------


def test_bn_destroys_sparsity_and_double_mask_restores(rng):
    x = jnp.asarray(rng.standard_normal((32, 100), dtype=np.float32))
    mask = jnp.asarray((rng.random((32, 100)) < 0.2).astype(np.float32))
    s = jax.nn.relu(x) * mask  # sparse activations (80% zeros at least)
    z_before = float((np.asarray(s) == 0).mean())
    bn = L.init_bn(100)
    st = L.init_bn_state(100)
    y, _ = L.batchnorm(s, bn, st, train=True, axes=(0,))
    z_after = float((np.asarray(y) == 0).mean())
    y_remask = np.asarray(y * mask)
    z_remask = (y_remask == 0).mean()
    mask_zero = float((np.asarray(mask) == 0).mean())
    assert z_before > 0.8
    assert z_after < 0.05, "BN shift should destroy zero-sparsity"
    # the second mask restores the SELECTION sparsity (ReLU's extra zeros
    # within the kept set are legitimately shifted by BN)
    assert z_remask >= mask_zero - 1e-6, "double mask must restore mask sparsity"
    assert z_remask > 0.75


def test_bn_preserves_relative_order_per_channel(rng):
    """§2.3's justification: BN scales and shifts per channel, so the
    within-channel sort order of activations is unchanged (which is why
    re-applying the same mask is sound)."""
    x = jnp.asarray(rng.standard_normal((16, 10), dtype=np.float32))
    bn = {
        "scale": jnp.asarray(rng.uniform(0.5, 2.0, 10).astype(np.float32)),
        "bias": jnp.asarray(rng.standard_normal(10).astype(np.float32)),
    }
    st = L.init_bn_state(10)
    y, _ = L.batchnorm(x, bn, st, train=True, axes=(0,))
    xs, ys = np.asarray(x), np.asarray(y)
    for c in range(10):
        assert (np.argsort(xs[:, c]) == np.argsort(ys[:, c])).all()


# ---------------------------------------------------------------------------
# Expressive power: DSG never prunes weights (§2's key distinction)
# ---------------------------------------------------------------------------


def test_no_weight_is_ever_zeroed_by_training():
    m = M.get("mlp")
    key = jax.random.PRNGKey(5)
    p = M.init_params(key, m)
    bn, st = M.init_bn(m), M.init_bn_state(m)
    rs = M.init_projections(key, m)
    wps = M.project_all(m, p, rs)
    vel, vbn = T.init_velocities(p), T.init_velocities(bn)
    x = jax.random.normal(key, (m.batch,) + m.input_shape)
    y = jax.random.randint(key, (m.batch,), 0, 10)
    ts = jax.jit(T.make_train_step(m))
    state = (p, vel, bn, vbn, st)
    for i in range(5):
        out = ts(*state, wps, rs, x, y, jnp.float32(0.9), jnp.float32(0.05), jnp.int32(i))
        state = out[:5]
    w0 = np.asarray(state[0][0]["w"])
    # the graph is sparse per-sample, but no weight is pruned away
    assert (w0 != 0).mean() > 0.999


def test_different_samples_select_different_neurons():
    """The 'dynamic' in DSG: masks are input-dependent (Fig 4 / Fig 11b)."""
    m = M.get("mlp")
    key = jax.random.PRNGKey(6)
    p = M.init_params(key, m)
    bn, st = M.init_bn(m), M.init_bn_state(m)
    rs = M.init_projections(key, m)
    wps = M.project_all(m, p, rs)
    x = jax.random.normal(key, (m.batch,) + m.input_shape)
    cap = []
    M.forward(m, p, bn, st, wps, rs, x, jnp.float32(0.8), False, jnp.int32(0), capture=cap)
    mask = np.asarray(cap[0])  # (batch, 256)
    diffs = np.abs(mask[:-1] - mask[1:]).sum(axis=1)
    assert (diffs > 0).mean() > 0.95, "masks should differ across samples"
    # but not be totally random: average density honours gamma
    assert abs(mask.mean() - 0.2) < 0.1


def test_same_sample_selects_same_neurons():
    """Determinism: identical inputs produce identical masks."""
    m = M.get("mlp")
    key = jax.random.PRNGKey(7)
    p = M.init_params(key, m)
    bn, st = M.init_bn(m), M.init_bn_state(m)
    rs = M.init_projections(key, m)
    wps = M.project_all(m, p, rs)
    x0 = jax.random.normal(key, (1,) + m.input_shape)
    x = jnp.tile(x0, (m.batch, 1))
    cap = []
    M.forward(m, p, bn, st, wps, rs, x, jnp.float32(0.7), False, jnp.int32(0), capture=cap)
    mask = np.asarray(cap[0])
    assert (mask == mask[0]).all()


# ---------------------------------------------------------------------------
# Sparsity propagates to the stashed-activation tensors (the memory claim)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gamma", [0.5, 0.8])
def test_activation_zero_fraction_exceeds_gamma(gamma, rng):
    """After mask -> ReLU -> BN -> mask, the stashed activations must be
    at least gamma-sparse (ReLU only adds zeros) — this is what the ZVC
    compression in Fig 6 banks on."""
    m = M.get("mlp")
    key = jax.random.PRNGKey(8)
    p = M.init_params(key, m)
    bn, st = M.init_bn(m), M.init_bn_state(m)
    rs = M.init_projections(key, m)
    wps = M.project_all(m, p, rs)
    x = jax.random.normal(key, (m.batch,) + m.input_shape)

    # instrument: recompute layer-1 output exactly as dense_forward does
    out, _, _ = L.dense_forward(
        x, p[0], bn[0], st[0], wps[0], rs[0], jnp.float32(gamma),
        m.opts, True, jax.random.PRNGKey(0),
    )
    zfrac = float((np.asarray(out) == 0).mean())
    assert zfrac >= gamma - 0.05, f"activation sparsity {zfrac} < gamma {gamma}"
