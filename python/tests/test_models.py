"""Model zoo: shapes, DSG enumeration, projections, forward/backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import models as M
from compile import train as T

ALL = ["mlp", "lenet", "vgg8", "resnet8", "wrn8_2"]


def _setup(name, **opts):
    m = M.get(name)
    if opts:
        m = m.with_opts(**opts)
    key = jax.random.PRNGKey(0)
    p = M.init_params(key, m)
    bn = M.init_bn(m)
    st = M.init_bn_state(m)
    is_drs = m.opts.strategy == "drs"
    rs = M.init_projections(key, m) if is_drs else []
    wps = M.project_all(m, p, rs) if is_drs else []
    x = jax.random.normal(key, (m.batch,) + m.input_shape)
    return m, p, bn, st, wps, rs, x


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(name):
    m, p, bn, st, wps, rs, x = _setup(name)
    logits, new_st, dens = M.forward(
        m, p, bn, st, wps, rs, x, jnp.float32(0.5), False, jnp.int32(0)
    )
    assert logits.shape == (m.batch, m.n_classes)
    assert len(dens) == len(M.dsg_specs(m))
    assert len(new_st) == len(m.units)


@pytest.mark.parametrize("name", ALL)
def test_dsg_specs_consistent(name):
    m = M.get(name)
    specs = M.dsg_specs(m)
    shapes = M.projection_shapes(m)
    assert len(specs) == len(shapes)
    for (path, spec), (path2, k, d_in, n_out) in zip(specs, shapes):
        assert path == path2
        assert d_in == spec.d_in
        assert 1 <= k <= d_in


@pytest.mark.parametrize("name", ALL)
def test_projection_r_is_ternary(name):
    m = M.get(name)
    rs = M.init_projections(jax.random.PRNGKey(0), m)
    s3 = np.float32(np.sqrt(3.0))
    for r in rs:
        vals = np.unique(np.asarray(r))
        for v in vals:
            assert any(np.isclose(v, t, atol=1e-5) for t in (-s3, 0.0, s3)), v
        # ~1/3 nonzero (paper s=3 => 67% sparsity)
        nz = float((np.asarray(r) != 0).mean())
        assert 0.15 < nz < 0.5


def test_project_all_matches_ref():
    from compile.kernels import ref

    m, p, bn, st, wps, rs, x = _setup("lenet")
    specs = M.dsg_specs(m)
    idx = 0
    for i, u in enumerate(m.units):
        if isinstance(u, L.Dense) and not u.classifier:
            want = ref.project_weights(rs[idx], p[i]["w"])
            np.testing.assert_allclose(wps[idx], want, rtol=1e-4, atol=1e-4)
            idx += 1
        elif isinstance(u, L.Conv):
            wmat = p[i]["w"].reshape(u.c_out, -1).T
            want = ref.project_weights(rs[idx], wmat)
            np.testing.assert_allclose(wps[idx], want, rtol=1e-4, atol=1e-4)
            idx += 1


@pytest.mark.parametrize("gamma", [0.0, 0.5, 0.9])
def test_density_tracks_gamma(gamma):
    m, p, bn, st, wps, rs, x = _setup("mlp")
    _, _, dens = M.forward(
        m, p, bn, st, wps, rs, x, jnp.float32(gamma), True, jnp.int32(0)
    )
    for d in dens:
        if gamma == 0.0:
            assert float(d) == 1.0
        else:
            assert abs(float(d) - (1 - gamma)) < 0.12


def test_mask_capture_shapes():
    m, p, bn, st, wps, rs, x = _setup("lenet")
    cap = []
    M.forward(
        m, p, bn, st, wps, rs, x, jnp.float32(0.5), False, jnp.int32(0),
        capture=cap,
    )
    assert len(cap) == len(M.dsg_specs(m))
    assert cap[0].shape == (m.batch, 6, 28, 28)
    assert cap[-1].shape == (m.batch, 84)


def test_train_step_decreases_loss():
    """A few steps on a fixed batch must reduce loss (overfit check)."""
    m, p, bn, st, wps, rs, x = _setup("mlp")
    key = jax.random.PRNGKey(3)
    y = jax.random.randint(key, (m.batch,), 0, m.n_classes)
    vel, vbn = T.init_velocities(p), T.init_velocities(M.init_bn(m))
    bn = M.init_bn(m)
    ts = jax.jit(T.make_train_step(m))
    losses = []
    state = (p, vel, bn, vbn, st)
    for i in range(8):
        out = ts(*state, wps, rs, x, y, jnp.float32(0.5), jnp.float32(0.05), jnp.int32(i))
        state = out[:5]
        losses.append(float(out[5]))
    assert losses[-1] < losses[0] * 0.7, f"loss not decreasing: {losses}"


def test_train_step_dense_variant():
    m, p, bn, st, wps, rs, x = _setup("mlp", strategy="dense")
    key = jax.random.PRNGKey(3)
    y = jax.random.randint(key, (m.batch,), 0, m.n_classes)
    vel, vbn = T.init_velocities(p), T.init_velocities(bn)
    ts = jax.jit(T.make_train_step(m))
    out = ts(p, vel, bn, vbn, st, [], [], x, y, jnp.float32(0.5),
             jnp.float32(0.05), jnp.int32(0))
    out2 = ts(*out[:5], [], [], x, y, jnp.float32(0.5), jnp.float32(0.05),
              jnp.int32(1))
    assert float(out2[5]) < float(out[5])


def test_grad_sparsity_through_masks():
    """Algorithm 1: weight gradients of masked layers are column-sparse —
    a column (output neuron) never selected by ANY sample gets zero grad."""
    m, p, bn, st, wps, rs, x = _setup("mlp", use_bn=False)
    key = jax.random.PRNGKey(3)
    y = jax.random.randint(key, (m.batch,), 0, m.n_classes)
    gamma = jnp.float32(0.95)

    cap = []
    M.forward(m, p, M.init_bn(m), st, wps, rs, x, gamma, True, jnp.int32(0),
              capture=cap)
    mask1 = np.asarray(cap[0])  # (batch, 256) layer-1 selection mask
    never_selected = mask1.sum(axis=0) == 0.0
    assert never_selected.any(), "fixture needs some never-selected columns"

    def loss(p):
        logits, _, _ = M.forward(
            m, p, M.init_bn(m), st, wps, rs, x, gamma, True, jnp.int32(0)
        )
        return T.cross_entropy(logits, y)

    g = jax.grad(loss)(p)
    g1 = np.asarray(g[0]["w"])  # first dense layer grad (784, 256)
    dead_cols = np.abs(g1[:, never_selected]).max()
    assert dead_cols == 0.0, f"unselected columns must get zero grad: {dead_cols}"


def test_zoo_rejects_unknown():
    with pytest.raises(KeyError):
        M.get("alexnet")


def test_with_opts_and_rename():
    m = M.get("mlp").with_opts(eps=0.7).renamed("mlp7")
    assert m.opts.eps == 0.7 and m.name == "mlp7"
    assert M.get("mlp").opts.eps == 0.5  # original untouched


def test_cross_entropy_and_accuracy():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
    y = jnp.asarray([0, 1, 1])
    assert float(T.cross_entropy(logits, y)) > 0.0
    np.testing.assert_allclose(float(T.accuracy(logits, y)), 2 / 3, rtol=1e-6)


def test_sgd_momentum_update():
    p = {"w": jnp.ones((2, 2))}
    v = {"w": jnp.zeros((2, 2))}
    g = {"w": jnp.ones((2, 2))}
    new_p, new_v = T.sgd_momentum(p, v, g, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(new_v["w"]), -0.1)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 0.9)
    # momentum accumulates
    new_p2, new_v2 = T.sgd_momentum(new_p, new_v, g, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(new_v2["w"]), -0.19, rtol=1e-6)
