"""Shared pytest fixtures for the DSG python test suite."""

import os
import sys

# Tests run from python/ (see Makefile) but also support repo-root pytest.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PY = os.path.dirname(_HERE)
if _PY not in sys.path:
    sys.path.insert(0, _PY)

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(42)
