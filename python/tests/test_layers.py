"""L2 layer semantics: DRS selection, threshold sharing, double-mask BN."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import jll


def _arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# shared threshold (Appendix B / Fig 9)
# ---------------------------------------------------------------------------


def test_shared_threshold_gamma_zero_keeps_all(rng):
    v = _arr(rng, 4, 100)
    t = L.shared_threshold(v, jnp.float32(0.0))
    mask = (v >= t).astype(np.float32)
    # sample 0 keeps everything; other samples share the same threshold
    assert float(np.asarray(mask)[0].mean()) == 1.0


@pytest.mark.parametrize("gamma", [0.3, 0.5, 0.8, 0.9])
def test_shared_threshold_sample0_density(rng, gamma):
    """On the threshold-defining sample, density == 1 - gamma exactly
    (continuous values, no ties)."""
    v = _arr(rng, 8, 500)
    t = L.shared_threshold(v, jnp.float32(gamma))
    d0 = float((np.asarray(v[0]) >= float(t)).mean())
    assert abs(d0 - (1 - gamma)) < 2.5 / 500 + 1e-6


def test_shared_threshold_other_samples_approximate(rng):
    """Other samples share the threshold: density close to 1-gamma on
    average for iid activations (the paper's inter-sample sharing bet)."""
    v = _arr(rng, 64, 400)
    t = L.shared_threshold(v, jnp.float32(0.7))
    d = (np.asarray(v) >= float(t)).mean(axis=1)
    assert abs(d.mean() - 0.3) < 0.05


def test_shared_threshold_is_dynamic_in_gamma(rng):
    """One artifact serves all gammas: jit once, vary gamma at runtime."""
    v = _arr(rng, 2, 256)
    f = jax.jit(L.shared_threshold)
    d = []
    for g in (0.0, 0.5, 0.9):
        t = f(v, jnp.float32(g))
        d.append(float((np.asarray(v[0]) >= float(t)).mean()))
    assert d[0] == 1.0 and d[0] > d[1] > d[2]


# ---------------------------------------------------------------------------
# batchnorm
# ---------------------------------------------------------------------------


def test_batchnorm_normalizes(rng):
    x = _arr(rng, 32, 16) * 3.0 + 5.0
    bn = L.init_bn(16)
    st = L.init_bn_state(16)
    y, new_st = L.batchnorm(x, bn, st, train=True, axes=(0,))
    np.testing.assert_allclose(np.asarray(y).mean(axis=0), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y).std(axis=0), 1.0, atol=1e-2)
    # running stats moved toward the batch stats
    assert float(jnp.abs(new_st["mean"]).max()) > 0.0


def test_batchnorm_eval_uses_running_stats(rng):
    x = _arr(rng, 8, 4)
    bn = L.init_bn(4)
    st = {"mean": jnp.ones((4,)) * 2.0, "var": jnp.ones((4,)) * 4.0}
    y, new_st = L.batchnorm(x, bn, st, train=False, axes=(0,))
    np.testing.assert_allclose(
        np.asarray(y), (np.asarray(x) - 2.0) / np.sqrt(4.0 + L.BN_EPS), rtol=1e-5
    )
    assert new_st is st  # state untouched in eval


def test_batchnorm_conv_axes(rng):
    x = _arr(rng, 4, 8, 5, 5)
    bn = L.init_bn(8)
    st = L.init_bn_state(8)
    y, _ = L.batchnorm(x, bn, st, train=True, axes=(0, 2, 3))
    m = np.asarray(y).mean(axis=(0, 2, 3))
    np.testing.assert_allclose(m, 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# dense DSG layer
# ---------------------------------------------------------------------------


def _dense_fixture(rng, d_in=64, d_out=48, batch=16, eps=0.5):
    spec = L.Dense(d_in, d_out)
    key = jax.random.PRNGKey(0)
    p = L.init_dense(key, spec)
    bn = L.init_bn(d_out)
    st = L.init_bn_state(d_out)
    k = jll.projection_dim(eps, d_out, d_in)
    u = rng.random((k, d_in))
    r = np.zeros((k, d_in), np.float32)
    r[u < 1 / 6] = -np.sqrt(3)
    r[(u >= 1 / 6) & (u < 1 / 3)] = np.sqrt(3)
    r = jnp.asarray(r)
    from compile.kernels import projection as pj

    wp = pj.project_weights(r, p["w"])
    x = _arr(rng, batch, d_in)
    return spec, p, bn, st, wp, r, x


def test_dense_dsg_sparsity(rng):
    spec, p, bn, st, wp, r, x = _dense_fixture(rng)
    opts = L.DSGOptions()
    out, _, stats = L.dense_forward(
        x, p, bn, st, wp, r, jnp.float32(0.8), opts, True, jax.random.PRNGKey(1)
    )
    # output neurons masked twice: zero fraction >= gamma-ish
    zfrac = float((np.asarray(out) == 0.0).mean())
    assert zfrac > 0.6, f"double-masked output not sparse: {zfrac}"
    assert 0.1 < float(stats["mask_density"]) < 0.35


def test_dense_gamma0_equals_dense_strategy(rng):
    """gamma=0 must reduce DSG to the dense layer exactly."""
    spec, p, bn, st, wp, r, x = _dense_fixture(rng)
    out_dsg, _, _ = L.dense_forward(
        x, p, bn, st, wp, r, jnp.float32(0.0), L.DSGOptions(), True,
        jax.random.PRNGKey(1),
    )
    out_dense, _, _ = L.dense_forward(
        x, p, bn, st, None, None, jnp.float32(0.0),
        L.DSGOptions(strategy="dense"), True, jax.random.PRNGKey(1),
    )
    np.testing.assert_allclose(
        np.asarray(out_dsg), np.asarray(out_dense), rtol=1e-4, atol=1e-5
    )


def test_dense_single_vs_double_mask(rng):
    """Single-mask output loses sparsity after BN (Fig 1e / Fig 2c)."""
    spec, p, bn, st, wp, r, x = _dense_fixture(rng)
    g = jnp.float32(0.8)
    out_single, _, _ = L.dense_forward(
        x, p, bn, st, wp, r, g, L.DSGOptions(double_mask=False), True,
        jax.random.PRNGKey(1),
    )
    out_double, _, _ = L.dense_forward(
        x, p, bn, st, wp, r, g, L.DSGOptions(double_mask=True), True,
        jax.random.PRNGKey(1),
    )
    z_single = float((np.asarray(out_single) == 0.0).mean())
    z_double = float((np.asarray(out_double) == 0.0).mean())
    assert z_double > 0.6  # BN + remask restores sparsity
    assert z_single < 0.1  # BN shift destroys zeros (the paper's problem)


def test_dense_nobn(rng):
    spec, p, bn, st, wp, r, x = _dense_fixture(rng)
    out, new_st, _ = L.dense_forward(
        x, p, bn, st, wp, r, jnp.float32(0.5),
        L.DSGOptions(use_bn=False), True, jax.random.PRNGKey(1),
    )
    # relu output: non-negative, state unchanged
    assert float(np.asarray(out).min()) >= 0.0
    assert new_st is st


def test_oracle_strategy_masks_true_top(rng):
    """Oracle virtual acts == exact pre-acts: the kept set is the true
    top-k of sample 0."""
    spec, p, bn, st, wp, r, x = _dense_fixture(rng)
    from compile.kernels import ref

    opts = L.DSGOptions(strategy="oracle", use_bn=False)
    out, _, _ = L.dense_forward(
        x, p, bn, st, None, None, jnp.float32(0.5), opts, True,
        jax.random.PRNGKey(1),
    )
    y0 = np.asarray(ref.matmul(x, p["w"]))[0]
    kept = np.asarray(out)[0] != 0
    thresh = np.sort(y0)[len(y0) // 2]
    # every kept neuron is above-threshold positive (relu may zero some)
    assert all(y0[kept] >= thresh - 1e-6)


def test_dsgoptions_validation():
    with pytest.raises(ValueError):
        L.DSGOptions(strategy="nope").validate()
    with pytest.raises(ValueError):
        L.DSGOptions(eps=1.5).validate()


# ---------------------------------------------------------------------------
# conv DSG layer
# ---------------------------------------------------------------------------


def test_conv_dsg_matches_dense_path_at_gamma0(rng):
    spec = L.Conv(3, 8, ksize=3, pad=1)
    key = jax.random.PRNGKey(0)
    p = L.init_conv(key, spec)
    bn, st = L.init_bn(8), L.init_bn_state(8)
    k = jll.projection_dim(0.5, 8, spec.d_in)
    u = rng.random((k, spec.d_in))
    r = np.zeros((k, spec.d_in), np.float32)
    r[u < 1 / 6] = -np.sqrt(3)
    r[(u >= 1 / 6) & (u < 1 / 3)] = np.sqrt(3)
    r = jnp.asarray(r)
    from compile.kernels import projection as pj

    wp = pj.project_weights(r, p["w"].reshape(8, -1).T)
    x = _arr(rng, 4, 3, 10, 10)
    out_dsg, _, _ = L.conv_forward(
        x, p, bn, st, wp, r, jnp.float32(0.0), spec, L.DSGOptions(), True,
        jax.random.PRNGKey(1),
    )
    out_dense, _, _ = L.conv_forward(
        x, p, bn, st, None, None, jnp.float32(0.0), spec,
        L.DSGOptions(strategy="dense"), True, jax.random.PRNGKey(1),
    )
    np.testing.assert_allclose(
        np.asarray(out_dsg), np.asarray(out_dense), rtol=1e-4, atol=1e-5
    )


def test_conv_projection_consistency(rng):
    """Projecting windows via conv(x, R-as-kernel) must equal projecting
    im2col rows via the matmul kernel — the layout-identity DRS relies on."""
    from jax import lax

    c, ks, k = 3, 3, 7
    x = _arr(rng, 2, c, 8, 8)
    u = rng.random((k, c * ks * ks))
    r = np.zeros((k, c * ks * ks), np.float32)
    r[u < 1 / 6] = -np.sqrt(3)
    r[(u >= 1 / 6) & (u < 1 / 3)] = np.sqrt(3)
    r = jnp.asarray(r)
    # conv path
    rk = r.reshape(k, c, ks, ks)
    xp_conv = lax.conv_general_dilated(
        x, rk, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ) / jnp.sqrt(jnp.float32(k))
    # im2col path
    patches = lax.conv_general_dilated_patches(
        x, (ks, ks), (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*ks*ks, H, W)
    from compile.kernels import ref

    n, d, h, w_ = patches.shape
    rows = patches.transpose(0, 2, 3, 1).reshape(-1, d)
    xp_mat = ref.project(rows, r).reshape(n, h, w_, k).transpose(0, 3, 1, 2)
    np.testing.assert_allclose(
        np.asarray(xp_conv), np.asarray(xp_mat), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# JLL dimension model (Table 1 pinning lives in test_jll.py)
# ---------------------------------------------------------------------------


def test_projection_dim_clipping():
    assert jll.projection_dim(0.5, 8, 25) == 25  # clipped to d_in
    assert jll.projection_dim(0.5, 512, 4608) == 299


def test_projection_dim_for_specs():
    assert L.projection_dim_for(L.Dense(784, 256), 0.5) == jll.projection_dim(
        0.5, 256, 784
    )
    c = L.Conv(128, 256, 3)
    assert L.projection_dim_for(c, 0.5) == jll.projection_dim(0.5, 256, 1152)
