"""Pin the JLL dimension model to the paper's Table 1."""

import pytest

from compile import jll

# (n_PQ, n_CRS, n_K) -> {eps: (dim, mmacs)} — verbatim from Table 1.
TABLE1 = [
    (1024, 1152, 128, {0.3: (539, 67.37), 0.5: (232, 29.0), 0.7: (148, 18.5), 0.9: (119, 14.88)}),
    (256, 1152, 256, {0.3: (616, 38.5), 0.5: (266, 16.63), 0.7: (169, 10.56), 0.9: (136, 8.5)}),
    (256, 2304, 256, {0.3: (616, 38.5), 0.5: (266, 16.63), 0.7: (169, 10.56), 0.9: (136, 8.5)}),
    (64, 2304, 512, {0.3: (693, 21.65), 0.5: (299, 9.34), 0.7: (190, 5.94), 0.9: (154, 4.81)}),
    (64, 4608, 512, {0.3: (693, 21.65), 0.5: (299, 9.34), 0.7: (190, 5.94), 0.9: (154, 4.81)}),
]

BASELINE = {  # (n_PQ, n_CRS, n_K) -> BL MMACs
    (1024, 1152, 128): 144,
    (256, 1152, 256): 72,
    (256, 2304, 256): 144,
    (64, 2304, 512): 72,
    (64, 4608, 512): 144,
}


@pytest.mark.parametrize("row", TABLE1)
def test_dimension_matches_table1(row):
    n_pq, n_crs, n_k, per_eps = row
    for eps, (dim, _) in per_eps.items():
        got = jll.projection_dim(eps, n_k, n_crs)
        tol = 0.01 if eps < 0.9 else 0.07  # the 0.9 column is off-curve
        assert abs(got - dim) <= max(2, tol * dim), (
            f"eps={eps} n_K={n_k}: got {got}, paper {dim}"
        )


@pytest.mark.parametrize("row", TABLE1)
def test_mmacs_matches_table1(row):
    n_pq, n_crs, n_k, per_eps = row
    for eps, (dim, mmacs) in per_eps.items():
        # paper computes ops with *its* dim; use the published dim here so
        # this isolates the ops formula from the dim fit.
        got = jll.search_mmacs(n_pq, dim, n_k)
        assert abs(got - mmacs) / mmacs < 0.01, (
            f"eps={eps}: got {got:.2f}, paper {mmacs}"
        )


@pytest.mark.parametrize("shape,bl", sorted(BASELINE.items()))
def test_baseline_mmacs(shape, bl):
    n_pq, n_crs, n_k = shape
    got = jll.baseline_mmacs(n_pq, n_crs, n_k)
    assert abs(got - bl) / bl < 0.01


def test_dim_reduction_factors():
    """Paper Appendix B: average reduction 3.6x/8.5x/13.3x/16.5x."""
    want = {0.3: 3.6, 0.5: 8.5, 0.7: 13.3, 0.9: 16.5}
    for eps, factor in want.items():
        ratios = []
        for n_pq, n_crs, n_k, per in TABLE1:
            ratios.append(n_crs / jll.projection_dim(eps, n_k, n_crs))
        avg = sum(ratios) / len(ratios)
        assert abs(avg - factor) / factor < 0.15, f"eps={eps}: {avg} vs {factor}"


def test_eps_bounds():
    with pytest.raises(ValueError):
        jll.projection_dim(0.0, 128, 1152)
    with pytest.raises(ValueError):
        jll.projection_dim(1.0, 128, 1152)
    with pytest.raises(ValueError):
        jll.projection_dim(0.5, 0, 1152)


def test_monotonic_in_eps():
    dims = [jll.projection_dim(e, 256, 4096) for e in (0.2, 0.4, 0.6, 0.8)]
    assert dims == sorted(dims, reverse=True)
