"""AOT exporter invariants: meta layout, kept-input bookkeeping, HLO
parameter counts, and golden consistency.  Runs against the built
artifacts when present (skips cleanly otherwise)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, models as M, train as T

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _meta(name):
    path = os.path.join(ART, f"{name}.meta.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        return json.load(f)


def test_lower_to_file_reports_kept(tmp_path):
    def f(a, b, unused):
        return (a + b,)

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    kept = aot.lower_to_file(f, (spec, spec, spec), str(tmp_path / "f.hlo.txt"))
    assert kept == [0, 1]
    text = (tmp_path / "f.hlo.txt").read_text()
    assert "ENTRY" in text


def test_variant_registry_complete():
    fast = aot.build_variants(fast=True)
    full = aot.build_variants(fast=False)
    names = [n for n, _, _ in full]
    assert len(names) == len(set(names)), "duplicate variant names"
    assert {n for n, _, _ in fast} <= set(names)
    # every DESIGN.md experiment dependency is present
    for required in [
        "mlp", "mlp_dense", "lenet", "vgg8", "vgg8_dense", "resnet8",
        "wrn8_2", "vgg8s_oracle", "vgg8s_random", "vgg8s_single",
        "vgg8s_nobn", "vgg8s_eps30", "vgg8_d23", "resnet8_d7",
    ]:
        assert required in names, required


def test_meta_state_order_is_flatten_order():
    m = _meta("mlp")
    names = [s["name"] for s in m["state"]]
    groups = ["params.", "vel.", "bn.", "vbn.", "bn_state."]
    # group blocks appear in order
    idx = [min(i for i, n in enumerate(names) if n.startswith(g)) for g in groups]
    assert idx == sorted(idx)
    # within params: dict keys sorted (b before w for unit 2)
    p = [n for n in names if n.startswith("params.")]
    assert p == sorted(p)


def test_meta_counts_consistent():
    for name in ["mlp", "lenet"]:
        m = _meta(name)
        c = m["counts"]
        assert len(m["state"]) == c["params"] + c["vel"] + c["bn"] + c["vbn"] + c["bn_state"]
        assert len(m["wps"]) == c["wps"] == c["dsg"]
        assert len(m["rs"]) == c["rs"] == c["dsg"]
        assert len(m["dsg_weight_indices"]) == c["dsg"]
        assert len(m["dsg_layers"]) == c["dsg"]


def test_meta_kept_indices_valid():
    m = _meta("mlp")
    c = m["counts"]
    n_state = len(m["state"])
    n_train_inputs = n_state + c["wps"] + c["rs"] + 5  # x,y,gamma,lr,step
    kept = m["kept"]["train"]
    assert kept == sorted(set(kept))
    assert all(0 <= i < n_train_inputs for i in kept)
    # only `step` may be dropped for a drs variant
    dropped = set(range(n_train_inputs)) - set(kept)
    assert dropped <= {n_train_inputs - 1}


def test_hlo_parameter_count_matches_kept():
    m = _meta("mlp")
    path = os.path.join(ART, m["files"]["train"])
    text = open(path).read()
    # count parameters of the ENTRY computation only (fusion bodies also
    # contain parameter() instructions)
    entry = text[text.index("ENTRY "):]
    n_params = entry.count(" parameter(")
    assert n_params == len(m["kept"]["train"])


def test_units_topology_matches_model():
    m = _meta("lenet")
    kinds = [u["kind"] for u in m["units"]]
    assert kinds == [
        "conv", "maxpool", "conv", "maxpool", "flatten",
        "dense", "dense", "classifier",
    ]
    assert m["units"][0]["c_out"] == 6
    assert m["units"][-1]["d_out"] == 10


def test_golden_index_consistent():
    base = os.path.join(ART, "golden", "mlp_step")
    if not os.path.exists(base + ".json"):
        pytest.skip("artifacts not built")
    with open(base + ".json") as f:
        idx = json.load(f)
    size = os.path.getsize(base + ".bin")
    end = max(e["offset"] + e["nbytes"] for e in idx)
    assert end == size
    # offsets are contiguous and non-overlapping
    sorted_idx = sorted(idx, key=lambda e: e["offset"])
    pos = 0
    for e in sorted_idx:
        assert e["offset"] == pos
        pos += e["nbytes"]
    ins = [e for e in idx if e["name"].startswith("in")]
    outs = [e for e in idx if e["name"].startswith("out")]
    assert len(ins) == 29 and len(outs) == 24


def test_golden_outputs_reproducible():
    """Re-running the train step on the golden inputs reproduces the
    golden outputs (python-side determinism check)."""
    base = os.path.join(ART, "golden", "mlp_step")
    if not os.path.exists(base + ".json"):
        pytest.skip("artifacts not built")
    with open(base + ".json") as f:
        idx = json.load(f)
    raw = open(base + ".bin", "rb").read()

    def load(e):
        buf = raw[e["offset"]:e["offset"] + e["nbytes"]]
        dt = np.float32 if e["dtype"] == "f32" else np.int32
        return jnp.asarray(np.frombuffer(buf, dt).reshape(e["shape"]))

    tensors = {e["name"]: load(e) for e in idx}
    model = M.get("mlp")
    flat_in = [tensors[f"in{i}"] for i in range(29)]
    # rebuild the pytree args from flat leaves
    params = M.init_params(jax.random.PRNGKey(0), model)
    bn = M.init_bn(model)
    st = M.init_bn_state(model)
    vel = T.init_velocities(params)
    vbn = T.init_velocities(bn)
    rs = M.init_projections(jax.random.PRNGKey(0), model)
    wps = M.project_all(model, params, rs)
    example = (params, vel, bn, vbn, st, wps, rs, None, None, None, None, None)
    treedef = jax.tree_util.tree_structure(
        (params, vel, bn, vbn, st, wps, rs, 0.0, 0.0, 0.0, 0.0, 0.0)
    )
    del example
    args = jax.tree_util.tree_unflatten(treedef, flat_in)
    outs = jax.jit(T.make_train_step(model))(*args)
    flat_out = jax.tree_util.tree_leaves(outs)
    assert len(flat_out) == 24
    worst = 0.0
    for i, got in enumerate(flat_out):
        want = tensors[f"out{i}"]
        worst = max(worst, float(jnp.max(jnp.abs(got - want))))
    assert worst < 5e-3, f"golden replay diverged by {worst}"
