"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including odd, non-multiple-of-block sizes, which
exercise the pick_block divisor fallback) and checks allclose; plus
directed edge cases (1x1, single row/col, all-masked, threshold ties).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_matmul as mm
from compile.kernels import projection as pj
from compile.kernels import ref
from compile.kernels import topk_mask as tk
from compile.kernels._tiling import pick_block, pad_to_multiple, vmem_bytes

DIM = st.integers(min_value=1, max_value=97)


def _arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# tiling helpers
# ---------------------------------------------------------------------------


@given(dim=st.integers(1, 4096), pref=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_pick_block_is_divisor(dim, pref):
    b = pick_block(dim, pref)
    assert 1 <= b <= min(dim, pref)
    assert dim % b == 0


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_block(0, 8)


def test_pad_to_multiple():
    x = jnp.ones((3, 5))
    y = pad_to_multiple(x, 1, 4)
    assert y.shape == (3, 8)
    assert float(y[:, 5:].sum()) == 0.0
    assert pad_to_multiple(x, 0, 3).shape == (3, 5)


def test_vmem_bytes():
    assert vmem_bytes((128, 128)) == 128 * 128 * 4
    assert vmem_bytes((128, 256), jnp.bfloat16) == 128 * 256 * 2


# ---------------------------------------------------------------------------
# matmul / masked matmul
# ---------------------------------------------------------------------------


@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(
        mm.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


@given(m=DIM, k=DIM, n=DIM, density=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_masked_matmul_matches_ref(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    mask = jnp.asarray((rng.random((m, n)) < density).astype(np.float32))
    np.testing.assert_allclose(
        mm.masked_matmul(x, w, mask),
        ref.masked_matmul(x, w, mask),
        rtol=1e-4,
        atol=1e-4,
    )


def test_masked_matmul_all_zero_mask(rng):
    x, w = _arr(rng, 16, 32), _arr(rng, 32, 8)
    mask = jnp.zeros((16, 8))
    assert float(jnp.abs(mm.masked_matmul(x, w, mask)).max()) == 0.0


def test_masked_matmul_identity_mask(rng):
    x, w = _arr(rng, 16, 32), _arr(rng, 32, 8)
    mask = jnp.ones((16, 8))
    np.testing.assert_allclose(
        mm.masked_matmul(x, w, mask), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_block_sweep(rng):
    """Tiling must not change the result."""
    x, w = _arr(rng, 64, 128), _arr(rng, 128, 96)
    want = ref.matmul(x, w)
    for bm, bn, bk in [(8, 8, 8), (64, 96, 128), (16, 32, 64), (1, 1, 1)]:
        got = mm.matmul_impl(x, w, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_jnp(rng):
    """custom_vjp backward == autodiff of the dense reference."""
    x, w = _arr(rng, 12, 20), _arr(rng, 20, 8)

    def f_pallas(x, w):
        return jnp.sum(mm.matmul(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum(ref.matmul(x, w) ** 2)

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, gw_r, rtol=1e-4, atol=1e-4)


def test_masked_matmul_grad_is_masked(rng):
    """Algorithm 1: gradients must be sparsified by the same mask."""
    x, w = _arr(rng, 10, 16), _arr(rng, 16, 6)
    mask = jnp.asarray((np.arange(60).reshape(10, 6) % 3 == 0).astype(np.float32))

    def f(x, w):
        return jnp.sum(mm.masked_matmul(x, w, mask))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)

    def f_ref(x, w):
        return jnp.sum(ref.masked_matmul(x, w, mask))

    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, gw_r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# projection
# ---------------------------------------------------------------------------


def _ternary(rng, k, d, s=3):
    u = rng.random((k, d))
    r = np.zeros((k, d), dtype=np.float32)
    r[u < 1 / (2 * s)] = -np.sqrt(s)
    r[(u >= 1 / (2 * s)) & (u < 1 / s)] = np.sqrt(s)
    return jnp.asarray(r)


@given(m=DIM, d=DIM, k=DIM, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_project_matches_ref(m, d, k, seed):
    rng = np.random.default_rng(seed)
    x, r = _arr(rng, m, d), _ternary(rng, k, d)
    np.testing.assert_allclose(
        pj.project(x, r), ref.project(x, r), rtol=1e-4, atol=1e-4
    )


@given(d=DIM, n=DIM, k=DIM, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_project_weights_matches_ref(d, n, k, seed):
    rng = np.random.default_rng(seed)
    w, r = _arr(rng, d, n), _ternary(rng, k, d)
    np.testing.assert_allclose(
        pj.project_weights(r, w), ref.project_weights(r, w), rtol=1e-4, atol=1e-4
    )


def test_projection_shape_mismatch_raises(rng):
    with pytest.raises(AssertionError):
        pj.project(_arr(rng, 4, 10), _ternary(rng, 3, 11))


def test_inner_product_preservation(rng):
    """JLL (paper eq. 4): low-dim inner products approximate high-dim ones.

    Statistical check: with k=256, d=2048, the mean relative error over
    many (x, w) pairs should be well under 20%.
    """
    d, k, n = 2048, 256, 50
    x = _arr(rng, n, d) / np.sqrt(d)
    w = _arr(rng, n, d) / np.sqrt(d)
    r = _ternary(rng, k, d)
    xp = np.asarray(pj.project(x, r))
    wp = np.asarray(pj.project(w, r))
    hi = np.sum(np.asarray(x) * np.asarray(w), axis=1)
    lo = np.sum(xp * wp, axis=1)
    # errors scale with ||x|| ||w|| ~ 1 here
    err = np.abs(hi - lo)
    assert err.mean() < 0.1, f"mean inner-product error too large: {err.mean()}"


# ---------------------------------------------------------------------------
# threshold mask / apply
# ---------------------------------------------------------------------------


@given(m=DIM, n=DIM, t=st.floats(-2.0, 2.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_threshold_mask_matches_ref(m, n, t, seed):
    rng = np.random.default_rng(seed)
    v = _arr(rng, m, n)
    np.testing.assert_array_equal(
        tk.threshold_mask(v, jnp.float32(t)),
        ref.threshold_mask(v, jnp.float32(t)),
    )


@given(m=DIM, n=DIM, t=st.floats(-2.0, 2.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_threshold_apply_matches_ref(m, n, t, seed):
    rng = np.random.default_rng(seed)
    y, v = _arr(rng, m, n), _arr(rng, m, n)
    np.testing.assert_allclose(
        tk.threshold_apply(y, v, jnp.float32(t)),
        ref.threshold_apply(y, v, t),
        rtol=1e-5,
        atol=1e-6,
    )


def test_threshold_apply_4d(rng):
    """Conv activations (N,C,H,W) go through the 2-D reshape path."""
    y = _arr(rng, 2, 3, 8, 8)
    v = _arr(rng, 2, 3, 8, 8)
    got = tk.threshold_apply(y, v, jnp.float32(0.1))
    want = ref.threshold_apply(y, v, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_threshold_tie_values(rng):
    """Values exactly equal to the threshold are kept (>= semantics)."""
    v = jnp.asarray([[0.5, 0.5, 0.4, 0.6]], jnp.float32)
    m = tk.threshold_mask(v, jnp.float32(0.5))
    np.testing.assert_array_equal(np.asarray(m), [[1.0, 1.0, 0.0, 1.0]])


def test_threshold_apply_grad_is_masked(rng):
    """Backward masking: grad passes through the mask, zero elsewhere."""
    y, v = _arr(rng, 6, 9), _arr(rng, 6, 9)
    t = jnp.float32(0.2)
    g = jax.grad(lambda y: jnp.sum(tk.threshold_apply(y, v, t)))(y)
    np.testing.assert_allclose(g, ref.threshold_mask(v, t), rtol=1e-6)
