//! Quickstart: load a DSG artifact, run a few sparse training steps, and
//! inspect what the dynamic sparse graph is doing.
//!
//!     make artifacts && cargo run --release --example quickstart

use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::runtime::{Meta, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // The artifact was AOT-lowered from JAX+Pallas once; python is not
    // involved from here on.
    let meta = Meta::load(&dir, "mlp")?;
    println!(
        "loaded {}: {} params, batch {}, {} DSG layers (eps {})",
        meta.name,
        meta.param_elems(),
        meta.batch,
        meta.counts.dsg,
        meta.eps
    );
    for l in &meta.dsg_layers {
        println!(
            "  DSG layer {}: d={} projected to k={} ({:.1}x reduction)",
            l.path,
            l.d_in,
            l.k,
            l.d_in as f64 / l.k as f64
        );
    }

    let mut trainer = Trainer::new(&rt, meta, 42)?;
    let data = datasets::fashion_like(512, 42);
    let mut batches = datasets::BatchIter::new(&data, trainer.meta.batch, 1);

    // Train 20 steps at 50% sparsity: only half the output neurons of
    // each layer are computed, chosen per-sample by the dimension-
    // reduction search.
    println!("\nstep  loss    acc    mask densities (per DSG layer)");
    for step in 0..20 {
        let (xs, ys) = batches.next_batch();
        let out = trainer.step(&xs, &ys, 0.5, 0.05)?;
        if step % 4 == 0 {
            println!(
                "{:>4}  {:.4}  {:.3}  {:?}",
                step,
                out.loss,
                out.acc,
                out.densities.iter().map(|d| (d * 100.0).round() / 100.0).collect::<Vec<_>>()
            );
        }
    }

    // Sparsity is a runtime knob: the SAME artifact serves any gamma.
    println!("\nsame artifact, different sparsity levels:");
    let (xs, ys) = batches.next_batch();
    for gamma in [0.0, 0.3, 0.8, 0.95] {
        let out = trainer.step(&xs, &ys, gamma, 0.0)?; // lr 0: just probe
        println!(
            "  gamma {:>4}: densities {:?}",
            gamma,
            out.densities.iter().map(|d| (d * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
