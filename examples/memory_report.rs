//! Representational-cost report (Fig 6) with REAL compressed bytes:
//! trains a small model, captures its actual activation sparsity from
//! the probe artifact, runs the ZVC codec on real mask tensors, and then
//! prints the analytic Fig 6 table for the paper's five CNNs.
//!
//!     cargo run --release --example memory_report [gamma]

use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::runtime::{HostTensor, Meta, Runtime};
use dsg::util::human_bytes;
use dsg::{costmodel, memmodel, zvc};

fn main() -> anyhow::Result<()> {
    let gamma: f32 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.8);

    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;
    let meta = Meta::load(&dir, "lenet")?;

    // short training to get representative activations
    let mut cfg = dsg::config::RunConfig::preset_for_model("lenet");
    cfg.steps = 60;
    cfg.eval_every = 0;
    let data = datasets::fashion_like(1024, 11);
    let (train, test) = data.split(0.25);
    let mut trainer = Trainer::new(&rt, meta.clone(), 11)?;
    trainer.train(&cfg, &train, &test)?;

    // probe: full masks for one batch -> real measured sparsity + ZVC
    let probe = rt.load_artifact(&meta, "probe")?;
    let mut inputs: Vec<HostTensor> = Vec::new();
    inputs.extend(trainer.state.params(&meta).iter().cloned());
    inputs.extend(trainer.state.bn(&meta).iter().cloned());
    inputs.extend(trainer.state.bn_state(&meta).iter().cloned());
    inputs.extend(trainer.state.wps.iter().cloned());
    inputs.extend(trainer.state.rs.iter().cloned());
    let (xs, _) = datasets::BatchIter::new(&test, meta.batch, 1).next_batch();
    let mut shape = vec![meta.batch];
    shape.extend_from_slice(&meta.input_shape);
    inputs.push(HostTensor::f32(&shape, xs));
    inputs.push(HostTensor::scalar_f32(gamma));
    let inputs = meta.filter_kept("probe", inputs);
    let outs = probe.run(&inputs)?;

    println!("measured on trained lenet @ gamma {gamma}:");
    let mut total_dense = 0usize;
    let mut total_zvc = 0usize;
    for (i, mask) in outs[1..].iter().enumerate() {
        let m = mask.as_f32()?;
        // the masked activation tensor is at least as sparse as the mask
        let sparsity = 1.0 - m.iter().sum::<f32>() as f64 / m.len() as f64;
        let c = zvc::compress(m);
        total_dense += c.dense_nbytes();
        total_zvc += zvc::zvc_bytes(m.len(), sparsity);
        println!(
            "  layer {:>2}: {:>8} elems, mask sparsity {:.2}, zvc-at-sparsity {:>9} vs dense {:>9}",
            i,
            m.len(),
            sparsity,
            human_bytes(zvc::zvc_bytes(m.len(), sparsity) as u64),
            human_bytes(c.dense_nbytes() as u64)
        );
    }
    println!(
        "  total: {} -> {} ({:.2}x)\n",
        human_bytes(total_dense as u64),
        human_bytes(total_zvc as u64),
        total_dense as f64 / total_zvc as f64
    );

    // Fig 6 analytic table at the published model shapes
    let s = memmodel::effective_sparsity(gamma as f64, 0.5);
    println!("Fig 6 (paper shapes) @ activation sparsity {s:.2}:");
    println!(
        "{:<10} {:>6} {:>12} {:>12} {:>9} {:>8} {:>8}",
        "model", "batch", "dense-train", "dsg-train", "train-x", "act-x", "infer-x"
    );
    for net in costmodel::shapes::fig6_nets() {
        let m = memmodel::memory(&net, s);
        println!(
            "{:<10} {:>6} {:>12} {:>12} {:>8.2}x {:>7.2}x {:>7.2}x",
            net.name,
            net.batch,
            human_bytes(m.train_dense()),
            human_bytes(m.train_dsg()),
            m.train_reduction(),
            m.act_reduction(),
            m.infer_reduction()
        );
    }
    println!("\nmemory_report OK");
    Ok(())
}
