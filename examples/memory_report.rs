//! Representational-cost report (Fig 6) with REAL measured bytes:
//! trains a small conv model on the NATIVE engine (no PJRT, no
//! artifacts), with the training tape stored ZVC-compressed, and prints
//! the per-record measured footprint next to the analytic prediction —
//! then the analytic Fig 6 table for the paper's five CNNs.
//!
//!     cargo run --release --example memory_report [gamma]

use dsg::coordinator::NativeTrainer;
use dsg::native::train::TapeStorage;
use dsg::native::zoo;
use dsg::util::{human_bytes, Pcg32};
use dsg::{costmodel, memmodel, zvc};

fn main() -> anyhow::Result<()> {
    let gamma: f32 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.5);

    // short native training on lenet to get representative activations
    let meta = zoo::synth_meta(&zoo::spec_for("lenet")?)?;
    let mut rng = Pcg32::seeded(11);
    let mut trainer = NativeTrainer::new(meta.clone(), 11)?.with_tape(TapeStorage::Zvc);
    for _ in 0..10 {
        let x = rng.normal_vec(meta.batch * meta.input_elems(), 1.0);
        let y: Vec<i32> = (0..meta.batch).map(|_| rng.below(meta.classes as u32) as i32).collect();
        trainer.step(&x, &y, gamma, 0.05)?;
    }

    let mem = trainer.tape_memory();
    println!("measured on natively trained lenet @ gamma {gamma} (ZVC tape):");
    println!(
        "  {:>4} {:>5} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "unit", "part", "elems", "sparsity", "dense", "stored", "analytic"
    );
    for a in mem.allocs() {
        // the cross-check the tests pin down: a compressed activation's
        // stored bytes ARE the zvc_bytes formula at its measured nnz
        let analytic = if a.is_act() {
            human_bytes(zvc::zvc_bytes_nnz(a.elems, a.nnz).min(4 * a.elems) as u64)
        } else {
            "-".to_string()
        };
        println!(
            "  {:>4} {:>5} {:>9} {:>8.2}% {:>9} {:>9} {:>10}",
            a.unit,
            a.part,
            a.elems,
            100.0 * a.sparsity(),
            human_bytes(a.dense_bytes),
            human_bytes(a.stored_bytes),
            analytic
        );
    }
    println!(
        "  peak {} vs dense {} -> {:.2}x tape, {:.2}x acts-only (measured sparsity {:.2})\n",
        human_bytes(mem.peak()),
        human_bytes(mem.dense_peak()),
        mem.reduction(),
        mem.act_reduction(),
        mem.act_sparsity()
    );

    // Fig 6 analytic table at the published model shapes
    let s = memmodel::effective_sparsity(gamma as f64, 0.5);
    println!("Fig 6 (paper shapes) @ activation sparsity {s:.2}:");
    println!(
        "{:<10} {:>6} {:>12} {:>12} {:>9} {:>8} {:>8}",
        "model", "batch", "dense-train", "dsg-train", "train-x", "act-x", "infer-x"
    );
    for net in costmodel::shapes::fig6_nets() {
        let m = memmodel::memory(&net, s);
        println!(
            "{:<10} {:>6} {:>12} {:>12} {:>8.2}x {:>7.2}x {:>7.2}x",
            net.name,
            net.batch,
            human_bytes(m.train_dense()),
            human_bytes(m.train_dsg()),
            m.train_reduction(),
            m.act_reduction(),
            m.infer_reduction()
        );
    }
    println!("\nmemory_report OK");
    Ok(())
}
