//! Batched inference serving: single-image requests flow through the
//! dynamic batcher (rust/src/serve) into either the HLO forward or the
//! NATIVE sparse engine (real column skipping), and we report latency
//! percentiles + throughput at several sparsity levels.  DSG "extends to
//! inference by using the same selection pattern" (§5) — the same
//! on-the-fly DRS runs per request batch.
//!
//!     cargo run --release --example inference_server [model] [requests]

use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::metrics::fmt_secs;
use dsg::native::{Mode, NativeModel};
use dsg::runtime::{Meta, Runtime};
use dsg::serve::{Batcher, Queue};
use dsg::Tensor;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("lenet").to_string();
    let n_requests: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(256);

    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;
    let meta = Meta::load(&dir, &model)?;
    let batch = meta.batch;
    let d = meta.input_elems();

    // Warm the model up with a short training run so BN stats are sane.
    let mut cfg = dsg::config::RunConfig::preset_for_model(&model);
    cfg.steps = 60;
    cfg.eval_every = 0;
    let data = if cfg.dataset == "fashion" {
        datasets::fashion_like(1024, 3)
    } else {
        datasets::cifar_like(1024, 3)
    };
    let (train, test) = data.split(0.25);
    let mut trainer = Trainer::new(&rt, meta.clone(), cfg.seed)?;
    let acc = trainer.train(&cfg, &train, &test)?;
    println!("serving {model}: batch {batch}, trained to eval acc {acc:.3}\n");

    let native = NativeModel::new(&meta, &trainer.state)?;
    let mut shape = vec![batch];
    shape.extend_from_slice(&meta.input_shape);

    println!(
        "{:<8} {:>7} {:>10} {:>10} {:>10} {:>11} {:>8}",
        "backend", "gamma", "p50", "p99", "mean", "imgs/sec", "batches"
    );
    for gamma in [0.0f32, 0.5, 0.8, 0.9] {
        for backend in ["hlo", "native"] {
            let mut queue = Queue::new();
            let mut it = datasets::BatchIter::new(&test, 1, 9);
            for _ in 0..n_requests {
                let (img, _) = it.next_batch();
                queue.push(img);
            }
            let mut batcher = Batcher::new(batch, d, meta.classes);
            let t0 = std::time::Instant::now();
            let _responses = match backend {
                "hlo" => batcher.pump(&mut queue, |xs| trainer.forward(xs, gamma))?,
                _ => batcher.pump(&mut queue, |xs| {
                    let xt = Tensor::new(&shape, xs.to_vec());
                    let out = native.forward(&xt, gamma, Mode::Dsg)?;
                    Ok(out.logits.into_data())
                })?,
            };
            let wall = t0.elapsed().as_secs_f64();
            let s = &batcher.stats;
            println!(
                "{:<8} {:>7} {:>10} {:>10} {:>10} {:>11.0} {:>8}",
                backend,
                gamma,
                fmt_secs(s.percentile(0.5)),
                fmt_secs(s.percentile(0.99)),
                fmt_secs(s.latencies.iter().sum::<f64>() / s.latencies.len() as f64),
                s.throughput(wall),
                s.batches
            );
        }
    }
    println!("\n(native = rust sparse engine with real column skipping; hlo = XLA-compiled forward)");
    println!("inference_server OK");
    Ok(())
}
