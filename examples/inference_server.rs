//! Batched concurrent inference serving: single-image requests flow into
//! the shared request queue, N worker threads drain FIFO batches through
//! the NATIVE sparse engine (real column skipping, routed through
//! `sparse::parallel`), and we report latency percentiles + throughput
//! per worker count.  DSG "extends to inference by using the same
//! selection pattern" (§5) — the same on-the-fly DRS runs per request
//! batch.
//!
//! Works fully offline on the synthetic DSG model; when HLO artifacts
//! and the `xla` feature are present it also serves a briefly-trained
//! real model for comparison.
//!
//!     cargo run --release --example inference_server [requests]

use dsg::metrics::fmt_secs;
use dsg::native::{Mode, NativeModel};
use dsg::serve::{ConcurrentServer, ServeReport, ServerConfig, SynthModel};
use dsg::sparse::parallel::n_threads;
use dsg::Tensor;
use std::sync::Arc;
use std::time::Duration;

fn print_row(label: &str, report: &ServeReport) {
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
        label,
        fmt_secs(report.latency.percentile(0.50)),
        fmt_secs(report.latency.percentile(0.95)),
        fmt_secs(report.latency.percentile(0.99)),
        fmt_secs(report.latency.mean()),
        report.throughput(),
        report.batches
    );
}

fn header() {
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "config", "p50", "p95", "p99", "mean", "imgs/sec", "batches"
    );
}

fn serve_sweep<F>(
    make_forward: impl Fn(usize) -> F,
    batch: usize,
    d: usize,
    classes: usize,
    images: &[Vec<f32>],
) where
    F: Fn(&[f32]) -> anyhow::Result<Vec<f32>> + Send + Sync + 'static,
{
    header();
    let cores = n_threads();
    let mut preds: Option<Vec<usize>> = None;
    for workers in [1usize, 2, 4] {
        let intra = (cores / workers).max(1);
        let cfg = ServerConfig::new(workers, batch, d, classes)
            .with_max_wait(Duration::from_millis(5));
        // pre-enqueued drain => deterministic batch boundaries
        let report =
            ConcurrentServer::serve_all(cfg, make_forward(intra), images.iter().cloned())
                .expect("serve failed");
        match &preds {
            None => preds = Some(report.predictions()),
            Some(want) => assert_eq!(
                want,
                &report.predictions(),
                "{workers}-worker predictions diverged"
            ),
        }
        print_row(&format!("{workers} workers x {intra}t"), &report);
    }
    println!("(predictions bit-identical across all worker counts)");
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(512);
    let gamma = 0.8f32;
    let batch = 32usize;

    // --- synthetic DSG model: always available ---
    println!("== synthetic DSG MLP (784-512-256), gamma {gamma}, {n_requests} requests ==\n");
    let probe = SynthModel::new(11, &[784, 512, 256], 10, gamma);
    let images: Vec<Vec<f32>> =
        (0..n_requests).map(|i| probe.synth_image(100 + i as u64)).collect();
    serve_sweep(
        |intra| {
            let m = Arc::new(
                SynthModel::new(11, &[784, 512, 256], 10, gamma).with_intra_threads(intra),
            );
            move |xs: &[f32]| m.forward(xs, batch)
        },
        batch,
        784,
        10,
        &images,
    );

    // --- real model through the native engine, when artifacts exist ---
    let dir = dsg::artifacts_dir();
    if !dir.join("index.json").exists() {
        println!("\n(no artifacts — skipped the trained-model section; run `make artifacts`)");
        println!("inference_server OK");
        return Ok(());
    }
    let meta = dsg::runtime::Meta::load(&dir, "lenet")?;
    let mut state = dsg::coordinator::ModelState::init(&meta, 3);
    // Prefer properly trained weights when the PJRT runtime is in the
    // build; otherwise serve the randomly initialized topology.
    match dsg::runtime::Runtime::cpu() {
        Ok(rt) => {
            let mut cfg = dsg::config::RunConfig::preset_for_model("lenet");
            cfg.steps = 60;
            cfg.eval_every = 0;
            let data = dsg::datasets::fashion_like(1024, 3);
            let (train, test) = data.split(0.25);
            let mut trainer = dsg::coordinator::Trainer::new(&rt, meta.clone(), cfg.seed)?;
            let acc = trainer.train(&cfg, &train, &test)?;
            println!("\n== lenet (native engine), trained to eval acc {acc:.3} ==\n");
            state = trainer.state;
        }
        Err(e) => {
            println!("\n== lenet (native engine), random init — {e} ==\n");
            dsg::native::project_host(&meta, &mut state)?;
        }
    }
    let native = Arc::new(NativeModel::new(&meta, &state)?);
    let mb = meta.batch;
    let d = meta.input_elems();
    let classes = meta.classes;
    let mut shape = vec![mb];
    shape.extend_from_slice(&meta.input_shape);
    let data = dsg::datasets::fashion_like(n_requests, 9);
    let images: Vec<Vec<f32>> = dsg::datasets::BatchIter::eval_batches(&data, 1)
        .into_iter()
        .map(|(xs, _, _)| xs)
        .collect();
    serve_sweep(
        |intra| {
            let nm = native.clone();
            let shape = shape.clone();
            move |xs: &[f32]| {
                let xt = Tensor::new(&shape, xs.to_vec());
                let out = nm.forward_threaded(&xt, gamma, Mode::Dsg, intra)?;
                Ok(out.logits.into_data())
            }
        },
        mb,
        d,
        classes,
        &images,
    );
    println!("\n(native = rust sparse engine with real column skipping)");
    println!("inference_server OK");
    Ok(())
}
