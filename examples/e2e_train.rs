//! End-to-end driver: full DSG training runs on the synthetic FASHION
//! workload through all three layers (rust coordinator -> AOT HLO ->
//! Pallas kernels), with the projected-weight refresh every 50 steps,
//! gamma warmup, LR decay, eval, loss-curve logging, and a final
//! memory/compute report.  This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_train [steps] [gamma]

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::runtime::{Meta, Runtime};
use dsg::{costmodel, memmodel};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(400);
    let gamma: f32 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.5);

    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;

    // -- train MLP and LeNet on the FASHION-like task -------------------
    for model in ["mlp", "lenet"] {
        let meta = Meta::load(&dir, model)?;
        let mut cfg = RunConfig::preset_for_model(model);
        cfg.steps = steps;
        cfg.eval_every = (steps / 4).max(1);
        cfg.gamma = GammaSchedule::Warmup { target: gamma, warmup: steps / 8 };
        cfg.train_size = 4096;
        cfg.test_size = 1024;

        let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
        let (train, test) = data
            .split(cfg.test_size as f64 / (cfg.train_size + cfg.test_size) as f64);

        println!(
            "=== {model}: {} params, batch {}, {} steps, target gamma {gamma} ===",
            meta.param_elems(),
            meta.batch,
            cfg.steps
        );
        let mut trainer = Trainer::new(&rt, meta, cfg.seed)?;
        let t0 = std::time::Instant::now();
        let acc = trainer.train(&cfg, &train, &test)?;
        let wall = t0.elapsed().as_secs_f64();

        println!("\nloss curve ({model}, smoothed over 20 steps):");
        let h = &trainer.history;
        for chunk_start in (0..h.steps.len()).step_by((steps / 10).max(1)) {
            let end = (chunk_start + 20).min(h.steps.len());
            let avg: f32 = h.steps[chunk_start..end].iter().map(|s| s.loss).sum::<f32>()
                / (end - chunk_start) as f32;
            let bar = "#".repeat((avg * 12.0).min(60.0) as usize);
            println!("  step {:>4}  loss {:>7.4}  {bar}", chunk_start, avg);
        }
        let dens = h.mean_densities(50);
        println!(
            "final: eval acc {:.3}, mean step {:.1}ms, wall {:.1}s, densities {:?}",
            acc,
            1e3 * h.total_secs() / h.steps.len() as f64,
            wall,
            dens.iter().map(|d| (d * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
        let csv = format!("/tmp/dsg_e2e_{model}.csv");
        h.write_csv(std::path::Path::new(&csv))?;
        println!("history -> {csv}\n");
    }

    // -- headline numbers in context ------------------------------------
    let sp = memmodel::effective_sparsity(gamma as f64, 0.5);
    println!("headline cost model at this run's sparsity (gamma {gamma}, act sparsity {sp:.2}):");
    for net in costmodel::shapes::fig6_nets() {
        let mem = memmodel::memory(&net, sp);
        let mac = costmodel::macs(&net, gamma as f64, 0.5);
        println!(
            "  {:<10} train mem {:>5.2}x  acts {:>5.2}x  train ops {:>5.2}x  infer ops {:>5.2}x",
            net.name,
            mem.train_reduction(),
            mem.act_reduction(),
            mac.train_reduction(),
            mac.infer_reduction()
        );
    }
    println!("\ne2e_train OK");
    Ok(())
}
