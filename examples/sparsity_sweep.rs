//! Sparsity-accuracy sweep (the Fig 5(a) protocol on the synthetic
//! workload): train the same model at several gamma levels and report
//! final eval accuracy — the knee should appear at high sparsity.
//!
//!     cargo run --release --example sparsity_sweep [model] [steps]

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::runtime::{Meta, Runtime};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("mlp").to_string();
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(150);

    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;
    let meta = Meta::load(&dir, &model)?;
    let mut cfg = RunConfig::preset_for_model(&model);
    cfg.steps = steps;
    cfg.eval_every = 0;

    let data = if cfg.dataset == "fashion" {
        datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed)
    } else {
        datasets::cifar_like(cfg.train_size + cfg.test_size, cfg.seed)
    };
    let (train, test) =
        data.split(cfg.test_size as f64 / (cfg.train_size + cfg.test_size) as f64);

    println!("sparsity sweep: {model}, {steps} steps each\n");
    println!("{:>8} {:>10} {:>10} {:>12}", "gamma", "eval-acc", "last-loss", "density");
    let mut results = Vec::new();
    for gamma in [0.0f32, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9] {
        cfg.gamma = GammaSchedule::Constant(gamma);
        let mut trainer = Trainer::new(&rt, meta.clone(), cfg.seed)?;
        let acc = trainer.train(&cfg, &train, &test)?;
        let dens = trainer.history.mean_densities(20);
        let mean_d = dens.iter().sum::<f32>() / dens.len().max(1) as f32;
        println!(
            "{:>8} {:>10.3} {:>10.4} {:>12.2}",
            gamma,
            acc,
            trainer.history.last_loss().unwrap_or(f32::NAN),
            mean_d
        );
        results.push((gamma, acc));
    }

    // the Fig 5a shape: flat-ish until ~0.6, knee by 0.9
    let base = results[0].1;
    let at90 = results.last().unwrap().1;
    println!(
        "\nacc at gamma=0: {base:.3}; at gamma=0.9: {at90:.3} (drop {:.3})",
        base - at90
    );
    println!("sparsity_sweep OK");
    Ok(())
}
