//! Perf probe: raw throughput of every host-side engine on the Table-1
//! conv2 shape — the quick health check behind EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example perf_probe

use dsg::drs::projection::{ternary_r, TernaryIndex};
use dsg::sparse;
use dsg::sparse::parallel;
use dsg::tensor::{ops, Tensor};
use dsg::util::Pcg32;

fn time5(mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        f();
    }
    t0.elapsed().as_secs_f64() / 5.0
}

fn main() {
    let (m, d, n) = (1024usize, 1152usize, 128usize);
    let flops = (2 * m * d * n) as f64;
    let mut rng = Pcg32::seeded(1);
    let x = Tensor::new(&[m, d], rng.normal_vec(m * d, 1.0));
    let w = Tensor::new(&[d, n], rng.normal_vec(d * n, 1.0));
    let wt = ops::transpose(&w);
    let k = dsg::costmodel::jll::projection_dim(0.5, n, d);
    let r = ternary_r(&mut rng, k, d, 3);
    let ridx = TernaryIndex::from_dense(&r);
    let wp = dsg::drs::project_weights_idx(&ridx, &w);
    let (mask90, rowmask90) = {
        let out = sparse::dsg_layer(&x, &wt, &wp, &ridx, 0.9);
        (out.mask.to_dense(), out.mask)
    };
    // a compound-kernel probe wants a realistically sparse input (mask
    // + relu zeros, ~60% sparse); the dense probes keep the raw x
    let xs = Tensor::new(
        &[m, d],
        x.data().iter().map(|&v| if v < 0.3 { 0.0 } else { v }).collect::<Vec<f32>>(),
    );
    let in_dens =
        xs.data().iter().filter(|v| **v != 0.0).count() as f32 / (m * d) as f32;

    println!("conv2 shape ({m} x {d} x {n}), k = {k}, {} threads available", parallel::n_threads());
    let t = time5(|| {
        let _ = ops::matmul_blocked(&x, &w);
    });
    println!("GEMM blocked      {:>8.1}ms  {:>6.1} GFLOP/s", t * 1e3, flops / t / 1e9);
    let t = time5(|| {
        let _ = parallel::matmul_parallel(&x, &w);
    });
    println!("GEMM parallel     {:>8.1}ms  {:>6.1} GFLOP/s", t * 1e3, flops / t / 1e9);
    let t = time5(|| {
        let _ = sparse::vmm(&x, &wt);
    });
    println!("VMM               {:>8.1}ms  {:>6.1} GFLOP/s", t * 1e3, flops / t / 1e9);
    let t = time5(|| {
        let _ = sparse::dsg_vmm(&x, &wt, &mask90);
    });
    println!("DSG vmm @90%      {:>8.1}ms  (effective {:>6.1} GFLOP/s of kept work)", t * 1e3, 0.1 * flops / t / 1e9);
    let t = time5(|| {
        let _ = parallel::dsg_vmm_parallel(&x, &wt, &mask90);
    });
    println!("DSG vmm par @90%  {:>8.1}ms", t * 1e3);
    let threads = parallel::n_threads();
    let (_, realized) =
        parallel::dsg_vmm_compound_parallel_with(&xs, &wt, &rowmask90, in_dens, threads);
    let t = time5(|| {
        let _ = parallel::dsg_vmm_compound_parallel_with(&xs, &wt, &rowmask90, in_dens, threads);
    });
    println!(
        "DSG compound @90% {:>8.1}ms  ({} realized madds at {:.0}% input density)",
        t * 1e3,
        dsg::metrics::ops::human_madds(realized),
        100.0 * in_dens
    );
    let t = time5(|| {
        let _ = dsg::drs::project_rows(&x, &r);
    });
    println!("DRS projection    {:>8.1}ms  ({} adds/row)", t * 1e3, ridx.adds_per_row());
    let t = time5(|| {
        let _ = parallel::project_rows_parallel(&x, &ridx);
    });
    println!("DRS proj parallel {:>8.1}ms", t * 1e3);
}
