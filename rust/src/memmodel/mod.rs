//! Representational-cost (memory) model — §3.3, Fig 6.
//!
//! Training footprint = weights + stashed activations of EVERY layer
//! (needed for backward) + the DSG selection masks (1 bit/element).
//! Inference footprint = weights + the largest single layer activation.
//!
//! DSG stores activations ZVC-compressed at the run's measured sparsity;
//! the mask overhead is what the paper reports as "<2%" (training) and
//! what can offset the gains on weight-dominated nets in inference
//! (ResNet152 at 50%, §3.3).

use crate::costmodel::shapes::NetShape;
use crate::zvc;

pub const F32: usize = 4;

/// Byte accounting for one network at one activation sparsity.
#[derive(Clone, Copy, Debug)]
pub struct MemBreakdown {
    pub weights: u64,
    pub acts_dense: u64,
    pub acts_zvc: u64,
    pub masks: u64,
    pub infer_act_dense: u64,
    pub infer_act_zvc: u64,
    pub infer_mask: u64,
}

impl MemBreakdown {
    pub fn train_dense(&self) -> u64 {
        self.weights + self.acts_dense
    }
    pub fn train_dsg(&self) -> u64 {
        self.weights + self.acts_zvc + self.masks
    }
    pub fn train_reduction(&self) -> f64 {
        self.train_dense() as f64 / self.train_dsg() as f64
    }
    /// Activation-only reduction (the paper's "up to 7.1x").
    pub fn act_reduction(&self) -> f64 {
        self.acts_dense as f64 / (self.acts_zvc + self.masks) as f64
    }
    pub fn infer_dense(&self) -> u64 {
        self.weights + self.infer_act_dense
    }
    pub fn infer_dsg(&self) -> u64 {
        self.weights + self.infer_act_zvc + self.infer_mask
    }
    pub fn infer_reduction(&self) -> f64 {
        self.infer_dense() as f64 / self.infer_dsg() as f64
    }
    /// Mask overhead relative to the DENSE training footprint (the
    /// paper's "minimal (<2%)" accounting; ours is slightly more
    /// conservative because we charge the full 1-bit bitmap per maskable
    /// activation element rather than sharing it with the ZVC bitmask).
    pub fn mask_frac(&self) -> f64 {
        self.masks as f64 / self.train_dense() as f64
    }
}

/// Compute the memory breakdown.
///
/// `act_sparsity` is the measured zero fraction of the (double-masked +
/// ReLU) activations; with DSG at sparsity gamma this is >= gamma (ReLU
/// zeros part of the kept set too).
pub fn memory(net: &NetShape, act_sparsity: f64) -> MemBreakdown {
    let b = net.batch as u64;
    let weights = net.total_weights() * F32 as u64;
    let acts_elems_batch = net.total_acts_per_sample() * b;
    let acts_dense = acts_elems_batch * F32 as u64;
    let acts_zvc = net
        .layers
        .iter()
        .map(|l| zvc::zvc_bytes(l.act_elems() * net.batch, act_sparsity) as u64)
        .sum();
    // masks: 1 bit per maskable activation element
    let masks: u64 = net
        .layers
        .iter()
        .filter(|l| l.maskable)
        .map(|l| ((l.act_elems() * net.batch).div_ceil(8)) as u64)
        .sum();
    let max_l = net
        .layers
        .iter()
        .max_by_key(|l| l.act_elems())
        .expect("net has layers");
    let infer_act_dense = (max_l.act_elems() * net.batch * F32) as u64;
    let infer_act_zvc = zvc::zvc_bytes(max_l.act_elems() * net.batch, act_sparsity) as u64;
    let infer_mask = if max_l.maskable {
        ((max_l.act_elems() * net.batch).div_ceil(8)) as u64
    } else {
        0
    };
    MemBreakdown {
        weights,
        acts_dense,
        acts_zvc,
        masks,
        infer_act_dense,
        infer_act_zvc,
        infer_mask,
    }
}

/// Effective activation sparsity for a DSG run at mask sparsity `gamma`:
/// the kept fraction still passes ReLU, which zeros about half of a
/// zero-mean pre-activation distribution.  Empirically (Fig 1f) the paper
/// sees >80% zeros even untrained; we model sparsity = gamma + relu_zero
/// * (1 - gamma) with relu_zero ~= 0.5 for the dense baseline's own
/// sparsity and use gamma directly as the conservative DSG floor.
pub fn effective_sparsity(gamma: f64, relu_zero: f64) -> f64 {
    gamma + relu_zero * (1.0 - gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::shapes::{fig6_nets, resnet152, vgg8};

    #[test]
    fn fig6_training_reduction_shape() {
        // Paper: avg 1.7x / 3.2x / 4.2x at 50/80/90% sparsity.  The Fig 6
        // x-axis is the *activation* sparsity the run achieves.
        let want = [(0.5, 1.7), (0.8, 3.2), (0.9, 4.2)];
        for (sparsity, target) in want {
            let mut rs = Vec::new();
            for net in fig6_nets() {
                rs.push(memory(&net, sparsity).train_reduction());
            }
            let avg = rs.iter().sum::<f64>() / rs.len() as f64;
            assert!(
                (avg - target).abs() / target < 0.40,
                "sparsity {sparsity}: avg train mem reduction {avg:.2} vs paper {target}"
            );
        }
    }

    #[test]
    fn activation_only_reduction_up_to_7x() {
        let net = vgg8(128);
        let m = memory(&net, effective_sparsity(0.9, 0.5));
        assert!(m.act_reduction() > 5.0, "{:.2}", m.act_reduction());
        assert!(m.act_reduction() < 9.0, "{:.2}", m.act_reduction());
    }

    #[test]
    fn mask_overhead_is_minimal() {
        // Paper: "<2%" vs the dense footprint; our conservative 1-bit-
        // per-element accounting lands just above, bounded at 4%.
        for net in fig6_nets() {
            let m = memory(&net, 0.8);
            assert!(m.mask_frac() < 0.04, "{}: {:.3}", net.name, m.mask_frac());
        }
    }

    #[test]
    fn resnet152_inference_mask_can_offset() {
        // §3.3: on ResNet152 at 50% the mask overhead ~offsets the
        // compression benefit in inference (weights dominate).
        let net = resnet152(32);
        let m = memory(&net, effective_sparsity(0.5, 0.5));
        assert!(m.infer_reduction() < 1.35, "{:.2}", m.infer_reduction());
    }

    #[test]
    fn training_reduction_monotone() {
        let net = vgg8(128);
        let r: Vec<f64> = [0.5, 0.7, 0.9]
            .iter()
            .map(|&g| memory(&net, effective_sparsity(g, 0.5)).train_reduction())
            .collect();
        assert!(r.windows(2).all(|w| w[1] > w[0]), "{r:?}");
    }

    #[test]
    fn inference_benefit_smaller_than_training() {
        // §3.3: inference gains < training gains (weights dominate there).
        for net in fig6_nets() {
            let s = effective_sparsity(0.8, 0.5);
            let m = memory(&net, s);
            assert!(
                m.infer_reduction() <= m.train_reduction() + 0.3,
                "{}: infer {:.2} vs train {:.2}",
                net.name,
                m.infer_reduction(),
                m.train_reduction()
            );
        }
    }

    #[test]
    fn effective_sparsity_bounds() {
        assert_eq!(effective_sparsity(0.0, 0.5), 0.5);
        assert!((effective_sparsity(0.8, 0.5) - 0.9).abs() < 1e-9);
        assert_eq!(effective_sparsity(1.0, 0.5), 1.0);
    }
}
