//! Run configuration: typed config with JSON load/save and presets.
//!
//! The config system is what makes the launcher reproducible: every
//! training/eval/bench run is fully described by a `RunConfig`, which can
//! be loaded from a JSON file, tweaked by CLI flags, and is stamped into
//! the run's output directory.

use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};

/// Sparsity schedule: how gamma evolves over training.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GammaSchedule {
    /// Constant gamma from step 0.
    Constant(f32),
    /// Linear warmup from 0 to the target over `warmup` steps (the
    /// paper's warm-up training, Appendix D).
    Warmup { target: f32, warmup: usize },
}

impl GammaSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            GammaSchedule::Constant(g) => g,
            GammaSchedule::Warmup { target, warmup } => {
                if warmup == 0 || step >= warmup {
                    target
                } else {
                    target * step as f32 / warmup as f32
                }
            }
        }
    }

    pub fn target(&self) -> f32 {
        match *self {
            GammaSchedule::Constant(g) => g,
            GammaSchedule::Warmup { target, .. } => target,
        }
    }
}

/// Full description of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// artifact variant name (e.g. "mlp", "vgg8", "vgg8s_oracle")
    pub model: String,
    pub gamma: GammaSchedule,
    pub lr: f32,
    /// multiplicative LR decay applied every `lr_decay_every` steps
    pub lr_decay: f32,
    /// 0 = never decay (the training loop must not take `step % 0`)
    pub lr_decay_every: usize,
    pub steps: usize,
    pub eval_every: usize,
    /// projected-weight refresh period (paper: every 50 iterations)
    pub refresh_every: usize,
    pub seed: u64,
    /// dataset: "fashion" or "cifar"
    pub dataset: String,
    pub train_size: usize,
    pub test_size: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "mlp".into(),
            gamma: GammaSchedule::Constant(0.5),
            lr: 0.05,
            lr_decay: 0.5,
            lr_decay_every: 400,
            steps: 300,
            eval_every: 100,
            refresh_every: 50,
            seed: 42,
            dataset: "fashion".into(),
            train_size: 2048,
            test_size: 512,
        }
    }
}

impl RunConfig {
    pub fn validate(&self) -> Result<()> {
        let g = self.gamma.target();
        if !(0.0..1.0).contains(&g) {
            bail!("gamma must be in [0,1), got {g}");
        }
        if self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.refresh_every == 0 {
            bail!("refresh_every must be > 0");
        }
        // lr_decay_every == 0 is legal and means "never decay"; the
        // decay factor itself must still be sane when it can apply
        if self.lr_decay_every > 0 && !(self.lr_decay > 0.0) {
            bail!("lr_decay must be positive, got {}", self.lr_decay);
        }
        if !matches!(self.dataset.as_str(), "fashion" | "cifar") {
            bail!("unknown dataset {:?}", self.dataset);
        }
        Ok(())
    }

    /// Dataset matching the artifact's input shape convention.
    pub fn preset_for_model(model: &str) -> RunConfig {
        let mut c = RunConfig { model: model.to_string(), ..Default::default() };
        if model.starts_with("mlp") || model.starts_with("lenet") {
            c.dataset = "fashion".into();
        } else {
            c.dataset = "cifar".into();
            c.train_size = 1024;
            c.test_size = 256;
            c.steps = 200;
        }
        c
    }

    pub fn to_json(&self) -> Json {
        let gamma = match self.gamma {
            GammaSchedule::Constant(g) => obj(vec![
                ("kind", Json::Str("constant".into())),
                ("value", Json::Num(g as f64)),
            ]),
            GammaSchedule::Warmup { target, warmup } => obj(vec![
                ("kind", Json::Str("warmup".into())),
                ("value", Json::Num(target as f64)),
                ("warmup", Json::Num(warmup as f64)),
            ]),
        };
        obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("gamma", gamma),
            ("lr", Json::Num(self.lr as f64)),
            ("lr_decay", Json::Num(self.lr_decay as f64)),
            ("lr_decay_every", Json::Num(self.lr_decay_every as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("refresh_every", Json::Num(self.refresh_every as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("dataset", Json::Str(self.dataset.clone())),
            ("train_size", Json::Num(self.train_size as f64)),
            ("test_size", Json::Num(self.test_size as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        if let Some(m) = j.get("model").and_then(|v| v.as_str()) {
            c.model = m.to_string();
        }
        if let Some(g) = j.get("gamma") {
            let value = g.req("value")?.as_f64().context("gamma.value")? as f32;
            c.gamma = match g.get("kind").and_then(|k| k.as_str()) {
                Some("warmup") => GammaSchedule::Warmup {
                    target: value,
                    warmup: g.req_usize("warmup")?,
                },
                _ => GammaSchedule::Constant(value),
            };
        }
        macro_rules! num {
            ($field:ident, $t:ty) => {
                if let Some(v) = j.get(stringify!($field)).and_then(|v| v.as_f64()) {
                    c.$field = v as $t;
                }
            };
        }
        num!(lr, f32);
        num!(lr_decay, f32);
        num!(lr_decay_every, usize);
        num!(steps, usize);
        num!(eval_every, usize);
        num!(refresh_every, usize);
        num!(seed, u64);
        num!(train_size, usize);
        num!(test_size, usize);
        if let Some(d) = j.get("dataset").and_then(|v| v.as_str()) {
            c.dataset = d.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        let txt = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let j = Json::parse(&txt).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default();
        c.model = "vgg8".into();
        c.gamma = GammaSchedule::Warmup { target: 0.8, warmup: 100 };
        c.dataset = "cifar".into();
        c.seed = 7;
        let j = c.to_json();
        let c2 = RunConfig::from_json(&j).unwrap();
        assert_eq!(c2.model, "vgg8");
        assert_eq!(c2.gamma, GammaSchedule::Warmup { target: 0.8, warmup: 100 });
        assert_eq!(c2.seed, 7);
        assert_eq!(c2.dataset, "cifar");
    }

    #[test]
    fn schedule_values() {
        let s = GammaSchedule::Warmup { target: 0.8, warmup: 100 };
        assert_eq!(s.at(0), 0.0);
        assert!((s.at(50) - 0.4).abs() < 1e-6);
        assert_eq!(s.at(100), 0.8);
        assert_eq!(s.at(1000), 0.8);
        assert_eq!(GammaSchedule::Constant(0.5).at(9), 0.5);
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = RunConfig::default();
        c.gamma = GammaSchedule::Constant(1.0);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.lr = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.dataset = "mnist".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.lr_decay = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lr_decay_every_zero_means_never() {
        // 0 is a legal "never decay" setting — it must validate (the
        // trainer guards the modulo) even with a nonsense decay factor
        let mut c = RunConfig::default();
        c.lr_decay_every = 0;
        c.validate().unwrap();
        c.lr_decay = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn presets() {
        assert_eq!(RunConfig::preset_for_model("mlp").dataset, "fashion");
        assert_eq!(RunConfig::preset_for_model("vgg8s_oracle").dataset, "cifar");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dsg_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        let c = RunConfig::default();
        c.save(&p).unwrap();
        let c2 = RunConfig::load(&p).unwrap();
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.steps, c.steps);
    }
}
