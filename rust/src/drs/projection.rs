//! Sparse ternary random projection (paper eq. 5-6), host side.
//!
//! R entries are {-sqrt(s), 0, +sqrt(s)} with P(+-) = 1/(2s); with s = 3
//! two thirds of R is zero, so the projection is genuinely
//! multiplication-free: we precompute, per output dimension, the index
//! lists of + and - entries and only add/subtract — exactly the
//! "negligible overhead" argument of §2.2.

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Sample a ternary (k, d) projection matrix.
pub fn ternary_r(rng: &mut Pcg32, k: usize, d: usize, s: u32) -> Tensor {
    Tensor::new(&[k, d], rng.ternary_vec(k * d, s))
}

/// Index-list form of a ternary R: per projected dim, which input dims to
/// add and which to subtract (the multiplication-free fast path).
#[derive(Clone, Debug)]
pub struct TernaryIndex {
    pub k: usize,
    pub d: usize,
    pub scale: f32, // sqrt(s) / sqrt(k)
    pub plus: Vec<Vec<u32>>,
    pub minus: Vec<Vec<u32>>,
}

impl TernaryIndex {
    pub fn from_dense(r: &Tensor) -> Self {
        let (k, d) = (r.shape()[0], r.shape()[1]);
        let mut plus = vec![Vec::new(); k];
        let mut minus = vec![Vec::new(); k];
        let mut mag = 0.0f32;
        for p in 0..k {
            for q in 0..d {
                let v = r.at2(p, q);
                if v > 0.0 {
                    plus[p].push(q as u32);
                    mag = v;
                } else if v < 0.0 {
                    minus[p].push(q as u32);
                    mag = -v;
                }
            }
        }
        TernaryIndex { k, d, scale: mag / (k as f32).sqrt(), plus, minus }
    }

    /// Project one row: y[p] = scale * (sum_plus x - sum_minus x).
    pub fn project_row(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d);
        debug_assert_eq!(out.len(), self.k);
        for p in 0..self.k {
            let mut acc = 0.0f32;
            for &q in &self.plus[p] {
                acc += x[q as usize];
            }
            for &q in &self.minus[p] {
                acc -= x[q as usize];
            }
            out[p] = acc * self.scale;
        }
    }

    /// Adds per projected row (the DRS overhead metric: no multiplies).
    pub fn adds_per_row(&self) -> usize {
        self.plus.iter().map(|v| v.len()).sum::<usize>()
            + self.minus.iter().map(|v| v.len()).sum::<usize>()
    }
}

/// Project rows of x (m, d) -> (m, k): f(X) = X R^T / sqrt(k).
pub fn project_rows(x: &Tensor, r: &Tensor) -> Tensor {
    let idx = TernaryIndex::from_dense(r);
    let m = x.shape()[0];
    let mut out = vec![0.0f32; m * idx.k];
    for i in 0..m {
        let row = &x.data()[i * idx.d..(i + 1) * idx.d];
        idx.project_row(row, &mut out[i * idx.k..(i + 1) * idx.k]);
    }
    Tensor::new(&[m, idx.k], out)
}

/// Project weights: f(W) = R W / sqrt(k).  w: (d, n) -> (k, n).
pub fn project_weights(r: &Tensor, w: &Tensor) -> Tensor {
    let idx = TernaryIndex::from_dense(r);
    let (d, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(d, idx.d, "w rows {d} != r cols {}", idx.d);
    let mut out = vec![0.0f32; idx.k * n];
    let wd = w.data();
    for p in 0..idx.k {
        let orow = &mut out[p * n..(p + 1) * n];
        for &q in &idx.plus[p] {
            let wrow = &wd[q as usize * n..(q as usize + 1) * n];
            for j in 0..n {
                orow[j] += wrow[j];
            }
        }
        for &q in &idx.minus[p] {
            let wrow = &wd[q as usize * n..(q as usize + 1) * n];
            for j in 0..n {
                orow[j] -= wrow[j];
            }
        }
        for v in orow.iter_mut() {
            *v *= idx.scale;
        }
    }
    Tensor::new(&[idx.k, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_naive, transpose};

    fn dense_project_rows(x: &Tensor, r: &Tensor) -> Tensor {
        let k = r.shape()[0] as f32;
        let mut y = matmul_naive(x, &transpose(r));
        for v in y.data_mut() {
            *v /= k.sqrt();
        }
        y
    }

    #[test]
    fn index_form_matches_dense_matmul() {
        let mut rng = Pcg32::seeded(31);
        let r = ternary_r(&mut rng, 16, 64, 3);
        let x = Tensor::new(&[8, 64], rng.normal_vec(8 * 64, 1.0));
        let got = project_rows(&x, &r);
        let want = dense_project_rows(&x, &r);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn project_weights_matches_dense() {
        let mut rng = Pcg32::seeded(32);
        let r = ternary_r(&mut rng, 12, 48, 3);
        let w = Tensor::new(&[48, 20], rng.normal_vec(48 * 20, 1.0));
        let got = project_weights(&r, &w);
        let k = 12f32;
        let mut want = matmul_naive(&r, &w);
        for v in want.data_mut() {
            *v /= k.sqrt();
        }
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn norm_preservation_jll() {
        // ||f(z)||^2 ~ ||z||^2 (paper eq. 3) statistically.
        let mut rng = Pcg32::seeded(33);
        let d = 2048;
        let k = 256;
        let r = ternary_r(&mut rng, k, d, 3);
        let mut errs = Vec::new();
        for _ in 0..20 {
            let x = Tensor::new(&[1, d], rng.normal_vec(d, 1.0));
            let fx = project_rows(&x, &r);
            let n0: f32 = x.data().iter().map(|v| v * v).sum();
            let n1: f32 = fx.data().iter().map(|v| v * v).sum();
            errs.push(((n1 - n0) / n0).abs());
        }
        let mean = errs.iter().sum::<f32>() / errs.len() as f32;
        assert!(mean < 0.12, "norm preservation error {mean}");
    }

    #[test]
    fn inner_product_preservation() {
        // |<f(x), f(w)> - <x, w>| small (paper eq. 4 / Fig 10c).
        let mut rng = Pcg32::seeded(34);
        let d = 1152;
        let k = 232; // eps = 0.5 for n_K = 128 per Table 1
        let r = ternary_r(&mut rng, k, d, 3);
        let mut errs = Vec::new();
        for _ in 0..30 {
            let x = Tensor::new(&[1, d], rng.normal_vec(d, (1.0 / d as f32).sqrt()));
            let w = Tensor::new(&[1, d], rng.normal_vec(d, (1.0 / d as f32).sqrt()));
            let hi: f32 = x.data().iter().zip(w.data()).map(|(a, b)| a * b).sum();
            let fx = project_rows(&x, &r);
            let fw = project_rows(&w, &r);
            let lo: f32 = fx.data().iter().zip(fw.data()).map(|(a, b)| a * b).sum();
            errs.push((hi - lo).abs());
        }
        let mean = errs.iter().sum::<f32>() / errs.len() as f32;
        assert!(mean < 0.1, "inner product error {mean}");
    }

    #[test]
    fn adds_per_row_is_sparse() {
        let mut rng = Pcg32::seeded(35);
        let r = ternary_r(&mut rng, 100, 900, 3);
        let idx = TernaryIndex::from_dense(&r);
        let adds = idx.adds_per_row();
        let frac = adds as f64 / (100.0 * 900.0);
        assert!((frac - 1.0 / 3.0).abs() < 0.03, "nonzero frac {frac}");
    }

    #[test]
    fn empty_rows_are_fine() {
        // A projected dim with no nonzeros yields exactly 0.
        let r = Tensor::zeros(&[2, 4]);
        let x = Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = project_rows(&x, &r);
        assert_eq!(y.data(), &[0.0, 0.0]);
    }
}
