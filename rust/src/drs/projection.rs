//! Sparse ternary random projection (paper eq. 5-6), host side.
//!
//! R entries are {-sqrt(s), 0, +sqrt(s)} with P(+-) = 1/(2s); with s = 3
//! two thirds of R is zero, so the projection is genuinely
//! multiplication-free: we precompute, per output dimension, the index
//! lists of + and - entries and only add/subtract — exactly the
//! "negligible overhead" argument of §2.2.

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Sample a ternary (k, d) projection matrix.
pub fn ternary_r(rng: &mut Pcg32, k: usize, d: usize, s: u32) -> Tensor {
    Tensor::new(&[k, d], rng.ternary_vec(k * d, s))
}

/// Index-list form of a ternary R: per projected dim, which input dims to
/// add and which to subtract (the multiplication-free fast path).
///
/// Layout is a flat SIGNED CSR: one index array + one offsets array of
/// `2k + 1` entries.  Projected dim `p` owns `idx[offsets[2p]..
/// offsets[2p + 1]]` as its + inputs and `idx[offsets[2p + 1]..
/// offsets[2p + 2]]` as its - inputs, both ascending.  One contiguous
/// allocation instead of `2k` nested `Vec`s: the per-row pointer chase
/// of the old `Vec<Vec<u32>>` disappears from `project_chunk`'s inner
/// loop, and the ± passes fuse into one walk of a single array.
///
/// The accumulation order is IDENTICAL to the nested form (+ indices in
/// ascending order, then - indices in ascending order, one add each):
/// projections are bit-for-bit what they were, which the DRS selection —
/// and therefore every downstream mask — depends on.  The unrolled
/// loops below keep that sequential order; reassociating into partial
/// sums would change selection bits and is deliberately NOT done.
#[derive(Clone, Debug)]
pub struct TernaryIndex {
    pub k: usize,
    pub d: usize,
    pub scale: f32, // sqrt(s) / sqrt(k)
    /// Signed-CSR offsets, `2k + 1` entries.
    offsets: Vec<usize>,
    /// Input-dim indices: per p, + run then - run, each ascending.
    idx: Vec<u32>,
}

/// Sequential 4-wide-unrolled `acc += x[q]` over an index run,
/// continuing from the caller's accumulator.  Same left-to-right
/// accumulation as a plain loop (bit-exact); the unroll only amortizes
/// loop/bounds overhead.
#[inline]
fn add_indexed(mut acc: f32, x: &[f32], qs: &[u32]) -> f32 {
    let mut t = 0;
    while t + 4 <= qs.len() {
        acc += x[qs[t] as usize];
        acc += x[qs[t + 1] as usize];
        acc += x[qs[t + 2] as usize];
        acc += x[qs[t + 3] as usize];
        t += 4;
    }
    while t < qs.len() {
        acc += x[qs[t] as usize];
        t += 1;
    }
    acc
}

/// Sequential 4-wide-unrolled `acc -= x[q]` twin of [`add_indexed`]:
/// the - run keeps subtracting from the SAME running accumulator, the
/// exact order the nested-Vec form used (a separate minus sum would
/// reassociate and change selection bits).
#[inline]
fn sub_indexed(mut acc: f32, x: &[f32], qs: &[u32]) -> f32 {
    let mut t = 0;
    while t + 4 <= qs.len() {
        acc -= x[qs[t] as usize];
        acc -= x[qs[t + 1] as usize];
        acc -= x[qs[t + 2] as usize];
        acc -= x[qs[t + 3] as usize];
        t += 4;
    }
    while t < qs.len() {
        acc -= x[qs[t] as usize];
        t += 1;
    }
    acc
}

impl TernaryIndex {
    pub fn from_dense(r: &Tensor) -> Self {
        let (k, d) = (r.shape()[0], r.shape()[1]);
        assert!(d <= u32::MAX as usize, "projection d {d} exceeds u32");
        let mut offsets = Vec::with_capacity(2 * k + 1);
        offsets.push(0);
        let mut idx = Vec::new();
        let mut mag = 0.0f32;
        for p in 0..k {
            let row = &r.data()[p * d..(p + 1) * d];
            for (q, &v) in row.iter().enumerate() {
                if v > 0.0 {
                    idx.push(q as u32);
                    mag = v;
                }
            }
            offsets.push(idx.len());
            for (q, &v) in row.iter().enumerate() {
                if v < 0.0 {
                    idx.push(q as u32);
                    mag = -v;
                }
            }
            offsets.push(idx.len());
        }
        TernaryIndex { k, d, scale: mag / (k as f32).sqrt(), offsets, idx }
    }

    /// The + input dims of projected dim `p` (ascending).
    #[inline]
    pub fn plus_row(&self, p: usize) -> &[u32] {
        &self.idx[self.offsets[2 * p]..self.offsets[2 * p + 1]]
    }

    /// The - input dims of projected dim `p` (ascending).
    #[inline]
    pub fn minus_row(&self, p: usize) -> &[u32] {
        &self.idx[self.offsets[2 * p + 1]..self.offsets[2 * p + 2]]
    }

    /// Project one row: `y[p] = scale * (sum_plus x - sum_minus x)`.
    /// Fused ± pass over the flat index array, 4-wide unrolled with
    /// sequential accumulation (bit-identical to the nested-Vec form).
    pub fn project_row(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d);
        debug_assert_eq!(out.len(), self.k);
        for (p, o) in out.iter_mut().enumerate() {
            let acc = sub_indexed(
                add_indexed(0.0, x, self.plus_row(p)),
                x,
                self.minus_row(p),
            );
            *o = acc * self.scale;
        }
    }

    /// Adds per projected row (the DRS overhead metric: no multiplies).
    pub fn adds_per_row(&self) -> usize {
        self.idx.len()
    }
}

/// Project rows of x (m, d) -> (m, k) through a prebuilt index:
/// f(X) = X R^T / sqrt(k).
pub fn project_rows_idx(x: &Tensor, idx: &TernaryIndex) -> Tensor {
    let m = x.shape()[0];
    let mut out = vec![0.0f32; m * idx.k];
    for i in 0..m {
        let row = &x.data()[i * idx.d..(i + 1) * idx.d];
        idx.project_row(row, &mut out[i * idx.k..(i + 1) * idx.k]);
    }
    Tensor::new(&[m, idx.k], out)
}

/// Project rows of x (m, d) -> (m, k): f(X) = X R^T / sqrt(k).
/// Compat wrapper that rebuilds the index; hot paths hold a prebuilt
/// [`TernaryIndex`] and call [`project_rows_idx`].
pub fn project_rows(x: &Tensor, r: &Tensor) -> Tensor {
    project_rows_idx(x, &TernaryIndex::from_dense(r))
}

/// Project weights through a prebuilt index: f(W) = R W / sqrt(k).
/// w: (d, n) -> (k, n).
pub fn project_weights_idx(idx: &TernaryIndex, w: &Tensor) -> Tensor {
    let (d, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(d, idx.d, "w rows {d} != r cols {}", idx.d);
    let mut out = vec![0.0f32; idx.k * n];
    let wd = w.data();
    for p in 0..idx.k {
        let orow = &mut out[p * n..(p + 1) * n];
        for &q in idx.plus_row(p) {
            let wrow = &wd[q as usize * n..(q as usize + 1) * n];
            for j in 0..n {
                orow[j] += wrow[j];
            }
        }
        for &q in idx.minus_row(p) {
            let wrow = &wd[q as usize * n..(q as usize + 1) * n];
            for j in 0..n {
                orow[j] -= wrow[j];
            }
        }
        for v in orow.iter_mut() {
            *v *= idx.scale;
        }
    }
    Tensor::new(&[idx.k, n], out)
}

/// Project weights: f(W) = R W / sqrt(k).  w: (d, n) -> (k, n).
/// Compat wrapper that rebuilds the index; hot paths hold a prebuilt
/// [`TernaryIndex`] and call [`project_weights_idx`].
pub fn project_weights(r: &Tensor, w: &Tensor) -> Tensor {
    project_weights_idx(&TernaryIndex::from_dense(r), w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul_naive, transpose};

    fn dense_project_rows(x: &Tensor, r: &Tensor) -> Tensor {
        let k = r.shape()[0] as f32;
        let mut y = matmul_naive(x, &transpose(r));
        for v in y.data_mut() {
            *v /= k.sqrt();
        }
        y
    }

    #[test]
    fn index_form_matches_dense_matmul() {
        let mut rng = Pcg32::seeded(31);
        let r = ternary_r(&mut rng, 16, 64, 3);
        let x = Tensor::new(&[8, 64], rng.normal_vec(8 * 64, 1.0));
        let got = project_rows(&x, &r);
        let want = dense_project_rows(&x, &r);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn project_weights_matches_dense() {
        let mut rng = Pcg32::seeded(32);
        let r = ternary_r(&mut rng, 12, 48, 3);
        let w = Tensor::new(&[48, 20], rng.normal_vec(48 * 20, 1.0));
        let got = project_weights(&r, &w);
        let k = 12f32;
        let mut want = matmul_naive(&r, &w);
        for v in want.data_mut() {
            *v /= k.sqrt();
        }
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn norm_preservation_jll() {
        // ||f(z)||^2 ~ ||z||^2 (paper eq. 3) statistically.
        let mut rng = Pcg32::seeded(33);
        let d = 2048;
        let k = 256;
        let r = ternary_r(&mut rng, k, d, 3);
        let mut errs = Vec::new();
        for _ in 0..20 {
            let x = Tensor::new(&[1, d], rng.normal_vec(d, 1.0));
            let fx = project_rows(&x, &r);
            let n0: f32 = x.data().iter().map(|v| v * v).sum();
            let n1: f32 = fx.data().iter().map(|v| v * v).sum();
            errs.push(((n1 - n0) / n0).abs());
        }
        let mean = errs.iter().sum::<f32>() / errs.len() as f32;
        assert!(mean < 0.12, "norm preservation error {mean}");
    }

    #[test]
    fn inner_product_preservation() {
        // |<f(x), f(w)> - <x, w>| small (paper eq. 4 / Fig 10c).
        let mut rng = Pcg32::seeded(34);
        let d = 1152;
        let k = 232; // eps = 0.5 for n_K = 128 per Table 1
        let r = ternary_r(&mut rng, k, d, 3);
        let mut errs = Vec::new();
        for _ in 0..30 {
            let x = Tensor::new(&[1, d], rng.normal_vec(d, (1.0 / d as f32).sqrt()));
            let w = Tensor::new(&[1, d], rng.normal_vec(d, (1.0 / d as f32).sqrt()));
            let hi: f32 = x.data().iter().zip(w.data()).map(|(a, b)| a * b).sum();
            let fx = project_rows(&x, &r);
            let fw = project_rows(&w, &r);
            let lo: f32 = fx.data().iter().zip(fw.data()).map(|(a, b)| a * b).sum();
            errs.push((hi - lo).abs());
        }
        let mean = errs.iter().sum::<f32>() / errs.len() as f32;
        assert!(mean < 0.1, "inner product error {mean}");
    }

    #[test]
    fn adds_per_row_is_sparse() {
        let mut rng = Pcg32::seeded(35);
        let r = ternary_r(&mut rng, 100, 900, 3);
        let idx = TernaryIndex::from_dense(&r);
        let adds = idx.adds_per_row();
        let frac = adds as f64 / (100.0 * 900.0);
        assert!((frac - 1.0 / 3.0).abs() < 0.03, "nonzero frac {frac}");
    }

    #[test]
    fn flat_csr_matches_nested_reference_bitwise() {
        // the flat signed-CSR walk must reproduce the original
        // nested-Vec accumulation order to the BIT: + adds in ascending
        // order, then - subtracts from the same running accumulator
        let mut rng = Pcg32::seeded(36);
        let (k, d) = (24, 150);
        let r = ternary_r(&mut rng, k, d, 3);
        let idx = TernaryIndex::from_dense(&r);
        let x: Vec<f32> = rng.normal_vec(d, 1.0);
        let mut got = vec![0.0f32; k];
        idx.project_row(&x, &mut got);
        for p in 0..k {
            let mut acc = 0.0f32;
            for q in 0..d {
                if r.at2(p, q) > 0.0 {
                    acc += x[q];
                }
            }
            for q in 0..d {
                if r.at2(p, q) < 0.0 {
                    acc -= x[q];
                }
            }
            assert_eq!(got[p].to_bits(), (acc * idx.scale).to_bits(), "dim {p}");
            // and the runs themselves are ascending / disjoint
            for w in idx.plus_row(p).windows(2) {
                assert!(w[0] < w[1]);
            }
            for w in idx.minus_row(p).windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn prebuilt_index_wrappers_match_compat_paths() {
        let mut rng = Pcg32::seeded(37);
        let r = ternary_r(&mut rng, 10, 40, 3);
        let idx = TernaryIndex::from_dense(&r);
        let x = Tensor::new(&[6, 40], rng.normal_vec(6 * 40, 1.0));
        let w = Tensor::new(&[40, 12], rng.normal_vec(40 * 12, 1.0));
        assert_eq!(project_rows(&x, &r), project_rows_idx(&x, &idx));
        assert_eq!(project_weights(&r, &w), project_weights_idx(&idx, &w));
    }

    #[test]
    fn empty_rows_are_fine() {
        // A projected dim with no nonzeros yields exactly 0.
        let r = Tensor::zeros(&[2, 4]);
        let x = Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = project_rows(&x, &r);
        assert_eq!(y.data(), &[0.0, 0.0]);
    }
}
