//! Top-k selection with inter-sample threshold sharing (Appendix B,
//! Fig 9) and the three selection strategies of Fig 5(c).

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Graph-selection strategy (Fig 5c).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionStrategy {
    /// Dimension-reduction search: select on projected virtual activations.
    Drs,
    /// Oracle: select on the exact pre-activations (upper bound).
    Oracle,
    /// Random selection (lower bound).
    Random,
}

impl SelectionStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "drs" => Some(Self::Drs),
            "oracle" => Some(Self::Oracle),
            "random" => Some(Self::Random),
            _ => None,
        }
    }
}

/// The top-k threshold of sample 0, shared across the batch.
///
/// `virt` is (batch, width); gamma in [0, 1) is the target sparsity.
/// Returns -inf for gamma == 0 so every neuron of every sample is kept
/// (mirrors `compile/layers.py::shared_threshold`).
pub fn shared_threshold(virt: &Tensor, gamma: f32) -> f32 {
    shared_threshold_scratch(virt, gamma, &mut Vec::new())
}

/// `shared_threshold` selecting from a caller-owned scratch buffer, so
/// the per-layer `to_vec` copy disappears in steady state (the buffer is
/// cleared and refilled, reusing its capacity).
pub fn shared_threshold_scratch(virt: &Tensor, gamma: f32, scratch: &mut Vec<f32>) -> f32 {
    shared_threshold_slice(virt.data(), virt.shape()[1], gamma, scratch)
}

/// Slice form of [`shared_threshold_scratch`]: `virt` is row-major
/// (batch, width) and only row 0 is consulted.  A zero-width layer has
/// nothing to rank, so the threshold degrades to keep-all (-inf) instead
/// of underflowing `width - 1`.
pub fn shared_threshold_slice(
    virt: &[f32],
    width: usize,
    gamma: f32,
    scratch: &mut Vec<f32>,
) -> f32 {
    assert!((0.0..1.0).contains(&gamma), "gamma out of range: {gamma}");
    if width == 0 {
        return f32::NEG_INFINITY;
    }
    let drop = ((gamma * width as f32).floor() as usize).min(width - 1);
    if drop == 0 {
        return f32::NEG_INFINITY;
    }
    scratch.clear();
    scratch.extend_from_slice(&virt[..width]);
    // select_nth_unstable gives the ascending-order element at `drop` in
    // O(n) — cheaper than the full sort the HLO path uses.
    let (_, nth, _) = scratch.select_nth_unstable_by(drop, |a, b| a.total_cmp(b));
    *nth
}

/// Compact selection mask: per-row selected-index lists in CSR form.
///
/// The dense f32 mask costs O(m*n) memory and forces the masked VMM to
/// branch-scan all n columns per row; this stores only the selected
/// indices (the paper's §3 memory argument applied to our own engine)
/// and lets the VMM jump straight to the selected neurons.  Indices are
/// ascending within a row, so engines visiting them reproduce the
/// dense-mask scan order bit-for-bit.
///
/// A keep-all mask (gamma = 0 / dense mode) is IMPLICIT: it stores one
/// shared `0..width` index row that [`RowMask::row`] serves for every
/// row, instead of materializing `rows * width` u32 indices.  Every
/// constructor canonicalizes to this form whenever the selection turns
/// out to be full, so structural equality (`==`) keeps working and
/// [`RowMask::nbytes`] — and with it the training-tape
/// [`crate::metrics::MemoryMeter`] accounting — charges O(width), not
/// O(rows * width), for the gamma-0 baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct RowMask {
    rows: usize,
    width: usize,
    /// Canonical keep-all flag: `idx` holds ONE shared `0..width` row
    /// and `offsets` collapses to `[0]`.
    full: bool,
    /// rows + 1 offsets into `idx` (just `[0]` when `full`).
    offsets: Vec<usize>,
    /// Selected column indices, ascending within each row (the shared
    /// `0..width` row when `full`).
    idx: Vec<u32>,
}

impl Default for RowMask {
    fn default() -> Self {
        RowMask::new()
    }
}

impl RowMask {
    /// An empty 0 x 0 mask (workspace placeholder; fill before use).
    pub fn new() -> RowMask {
        RowMask { rows: 0, width: 0, full: false, offsets: vec![0], idx: Vec::new() }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Selected column indices of row `i` (ascending).  A full mask
    /// serves the one shared `0..width` row for every `i`.
    pub fn row(&self, i: usize) -> &[u32] {
        if self.full {
            debug_assert!(i < self.rows);
            return &self.idx;
        }
        &self.idx[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Total selected entries.
    pub fn selected(&self) -> usize {
        if self.full {
            return self.rows * self.width;
        }
        self.idx.len()
    }

    /// Canonicalize a fully-selected explicit mask into the implicit
    /// keep-all form: keep the first row's `0..width` indices as the
    /// shared row, drop the per-row storage.
    fn canonicalize_full(&mut self) {
        if !self.full && self.rows * self.width > 0 && self.idx.len() == self.rows * self.width {
            self.full = true;
            self.idx.truncate(self.width); // row 0 IS 0..width when full
            self.offsets.clear();
            self.offsets.push(0);
        }
    }

    /// Heap bytes this mask holds (index list + offsets) — what the
    /// training-tape [`crate::metrics::MemoryMeter`] charges for the
    /// taped selection, the measured twin of the paper's "mask
    /// overhead" term in `memmodel`.
    pub fn nbytes(&self) -> usize {
        4 * self.idx.len() + std::mem::size_of::<usize>() * self.offsets.len()
    }

    /// Fraction of selected entries — the measured 1-gamma.
    pub fn density(&self) -> f64 {
        let total = self.rows * self.width;
        if total == 0 {
            return 0.0;
        }
        self.selected() as f64 / total as f64
    }

    /// True when every entry is selected (gamma = 0 keep-all): engines
    /// take a dense fast path with no index indirection.
    pub fn is_full(&self) -> bool {
        self.full
    }

    /// Rebuild in place from row-major virtual activations and a shared
    /// threshold, reusing the index storage (allocation-free once warm).
    pub fn fill_from_threshold(&mut self, virt: &[f32], rows: usize, width: usize, t: f32) {
        debug_assert_eq!(virt.len(), rows * width);
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        if t == f32::NEG_INFINITY {
            // keep-all threshold: every finite (and NaN-free) activation
            // passes `v >= -inf`, so skip the scan and go straight to
            // the implicit form.  NaN virt entries would fail the
            // comparison, but a NaN virtual activation means the run is
            // already lost — selection shape is the least of it.
            self.fill_full(rows, width);
            return;
        }
        self.full = false;
        self.rows = rows;
        self.width = width;
        self.offsets.clear();
        self.offsets.reserve(rows + 1);
        self.offsets.push(0);
        self.idx.clear();
        for i in 0..rows {
            let vrow = &virt[i * width..(i + 1) * width];
            for (j, &v) in vrow.iter().enumerate() {
                if v >= t {
                    self.idx.push(j as u32);
                }
            }
            self.offsets.push(self.idx.len());
        }
        self.canonicalize_full();
    }

    /// Rebuild in place as the keep-all mask (every column of every row
    /// selected) — equal to `fill_from_threshold` with a -inf threshold,
    /// without needing virtual activations (the dense-mode training
    /// path).  Stores one shared `0..width` row, NOT rows * width
    /// indices.
    pub fn fill_full(&mut self, rows: usize, width: usize) {
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        self.rows = rows;
        self.width = width;
        self.idx.clear();
        self.offsets.clear();
        if rows * width > 0 {
            self.full = true;
            self.idx.extend(0..width as u32);
            self.offsets.push(0);
        } else {
            // degenerate shape: empty explicit mask so `row(i)` still
            // works for width-0 rows
            self.full = false;
            self.offsets.resize(rows + 1, 0);
        }
    }

    /// Build from a (rows, width) virtual-activation tensor + threshold.
    pub fn from_threshold(virt: &Tensor, t: f32) -> RowMask {
        let mut m = RowMask::new();
        m.fill_from_threshold(virt.data(), virt.shape()[0], virt.shape()[1], t);
        m
    }

    /// Build from a dense (rows, width) 0/1 mask (nonzero = selected).
    pub fn from_dense(mask: &Tensor) -> RowMask {
        let (rows, width) = (mask.shape()[0], mask.shape()[1]);
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        let mut m = RowMask::new();
        m.rows = rows;
        m.width = width;
        m.offsets.clear();
        m.offsets.push(0);
        for i in 0..rows {
            let mrow = &mask.data()[i * width..(i + 1) * width];
            for (j, &v) in mrow.iter().enumerate() {
                if v != 0.0 {
                    m.idx.push(j as u32);
                }
            }
            m.offsets.push(m.idx.len());
        }
        m.canonicalize_full();
        m
    }

    /// Expand to a dense 0/1 f32 mask (tests / compat).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.width];
        for i in 0..self.rows {
            for &j in self.row(i) {
                out[i * self.width + j as usize] = 1.0;
            }
        }
        Tensor::new(&[self.rows, self.width], out)
    }
}

/// DRS selection as a compact [`RowMask`]: shared threshold from sample
/// 0, selection over the whole batch.
pub fn select_rowmask(virt: &Tensor, gamma: f32) -> RowMask {
    let t = shared_threshold(virt, gamma);
    RowMask::from_threshold(virt, t)
}

/// Binary selection mask for a (batch, width) virtual-activation matrix.
pub fn select_mask(
    virt: &Tensor,
    gamma: f32,
    strategy: SelectionStrategy,
    rng: &mut Pcg32,
) -> Tensor {
    let (batch, width) = (virt.shape()[0], virt.shape()[1]);
    match strategy {
        SelectionStrategy::Drs | SelectionStrategy::Oracle => {
            let t = shared_threshold(virt, gamma);
            Tensor::from_fn(&[batch, width], |i| {
                if virt.data()[i] >= t {
                    1.0
                } else {
                    0.0
                }
            })
        }
        SelectionStrategy::Random => {
            // keep ceil((1-gamma)*width) random neurons per sample
            let keep = width - ((gamma * width as f32).floor() as usize).min(width - 1);
            let mut mask = vec![0.0f32; batch * width];
            let mut idx: Vec<usize> = (0..width).collect();
            for b in 0..batch {
                rng.shuffle(&mut idx);
                for &j in idx.iter().take(keep) {
                    mask[b * width + j] = 1.0;
                }
            }
            Tensor::new(&[batch, width], mask)
        }
    }
}

/// Mask density (fraction of ones) — the measured 1-gamma.
pub fn mask_density(mask: &Tensor) -> f64 {
    if mask.is_empty() {
        return 0.0;
    }
    mask.data().iter().filter(|&&v| v != 0.0).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn gamma_zero_keeps_everything() {
        let mut rng = Pcg32::seeded(41);
        let v = randn(&mut rng, &[8, 100]);
        let m = select_mask(&v, 0.0, SelectionStrategy::Drs, &mut rng);
        assert_eq!(mask_density(&m), 1.0);
    }

    #[test]
    fn sample0_density_is_exact() {
        let mut rng = Pcg32::seeded(42);
        let v = randn(&mut rng, &[4, 1000]);
        for &g in &[0.3f32, 0.5, 0.8, 0.9] {
            let m = select_mask(&v, g, SelectionStrategy::Drs, &mut rng);
            let d0 = m.data()[..1000].iter().sum::<f32>() / 1000.0;
            let want = 1.0 - (g * 1000.0).floor() / 1000.0;
            assert!((d0 - want).abs() < 1e-6, "gamma {g}: {d0} vs {want}");
        }
    }

    #[test]
    fn shared_threshold_matches_sort() {
        let mut rng = Pcg32::seeded(43);
        let v = randn(&mut rng, &[2, 257]);
        let g = 0.7;
        let t = shared_threshold(&v, g);
        let mut row0: Vec<f32> = v.data()[..257].to_vec();
        row0.sort_by(|a, b| a.total_cmp(b));
        let drop = (g * 257.0).floor() as usize;
        assert_eq!(t, row0[drop]);
    }

    #[test]
    fn other_samples_share_threshold() {
        let mut rng = Pcg32::seeded(44);
        let v = randn(&mut rng, &[64, 500]);
        let m = select_mask(&v, 0.6, SelectionStrategy::Drs, &mut rng);
        let avg = mask_density(&m);
        assert!((avg - 0.4).abs() < 0.05, "avg density {avg}");
    }

    #[test]
    fn random_strategy_exact_per_sample() {
        let mut rng = Pcg32::seeded(45);
        let v = randn(&mut rng, &[16, 200]);
        let m = select_mask(&v, 0.75, SelectionStrategy::Random, &mut rng);
        for b in 0..16 {
            let kept: f32 = m.data()[b * 200..(b + 1) * 200].iter().sum();
            assert_eq!(kept, 50.0);
        }
    }

    #[test]
    fn oracle_keeps_true_top() {
        let v = Tensor::new(&[1, 4], vec![0.1, 5.0, -1.0, 3.0]);
        let m = select_mask(&v, 0.5, SelectionStrategy::Oracle, &mut Pcg32::seeded(1));
        assert_eq!(m.data(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(SelectionStrategy::parse("drs"), Some(SelectionStrategy::Drs));
        assert_eq!(SelectionStrategy::parse("oracle"), Some(SelectionStrategy::Oracle));
        assert_eq!(SelectionStrategy::parse("nope"), None);
    }

    #[test]
    #[should_panic]
    fn gamma_one_panics() {
        let v = Tensor::zeros(&[1, 4]);
        shared_threshold(&v, 1.0);
    }

    #[test]
    fn scratch_threshold_matches_plain() {
        let mut rng = Pcg32::seeded(46);
        let v = randn(&mut rng, &[4, 300]);
        let mut scratch = Vec::new();
        for &g in &[0.0f32, 0.3, 0.8, 0.95] {
            assert_eq!(
                shared_threshold(&v, g),
                shared_threshold_scratch(&v, g, &mut scratch),
                "gamma {g}"
            );
        }
    }

    #[test]
    fn rowmask_roundtrips_dense() {
        let mut rng = Pcg32::seeded(47);
        let v = randn(&mut rng, &[6, 40]);
        let dense = select_mask(&v, 0.6, SelectionStrategy::Drs, &mut rng);
        let rm = RowMask::from_dense(&dense);
        assert_eq!(rm.to_dense(), dense);
        assert_eq!(rm.density(), mask_density(&dense));
        // from_threshold agrees with the dense construction
        let t = shared_threshold(&v, 0.6);
        assert_eq!(RowMask::from_threshold(&v, t), rm);
        assert_eq!(select_rowmask(&v, 0.6), rm);
    }

    #[test]
    fn rowmask_rows_are_ascending() {
        let mut rng = Pcg32::seeded(48);
        let v = randn(&mut rng, &[5, 64]);
        let rm = select_rowmask(&v, 0.7);
        for i in 0..rm.rows() {
            let r = rm.row(i);
            for w in r.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        assert_eq!(
            rm.selected(),
            (0..rm.rows()).map(|i| rm.row(i).len()).sum::<usize>()
        );
    }

    #[test]
    fn rowmask_keep_all_is_full() {
        let mut rng = Pcg32::seeded(49);
        let v = randn(&mut rng, &[3, 32]);
        let rm = select_rowmask(&v, 0.0);
        assert!(rm.is_full());
        assert_eq!(rm.density(), 1.0);
        let partial = select_rowmask(&v, 0.5);
        assert!(!partial.is_full());
    }

    #[test]
    fn rowmask_fill_reuses_storage() {
        let mut rng = Pcg32::seeded(50);
        let v = randn(&mut rng, &[8, 128]);
        let t = shared_threshold(&v, 0.8);
        let mut rm = RowMask::new();
        rm.fill_from_threshold(v.data(), 8, 128, t);
        let first = rm.clone();
        // refill with a different shape, then back: same result
        rm.fill_from_threshold(&v.data()[..4 * 128], 4, 128, t);
        rm.fill_from_threshold(v.data(), 8, 128, t);
        assert_eq!(rm, first);
    }

    #[test]
    fn zero_width_threshold_keeps_all() {
        let mut scratch = Vec::new();
        for &g in &[0.0f32, 0.5, 0.99] {
            assert_eq!(
                shared_threshold_slice(&[], 0, g, &mut scratch),
                f32::NEG_INFINITY,
                "gamma {g}"
            );
        }
    }

    #[test]
    fn fill_full_matches_neg_inf_threshold() {
        let mut rng = Pcg32::seeded(51);
        let v = randn(&mut rng, &[4, 9]);
        let mut a = RowMask::new();
        a.fill_from_threshold(v.data(), 4, 9, f32::NEG_INFINITY);
        let mut b = RowMask::new();
        b.fill_full(4, 9);
        assert_eq!(a, b);
        assert!(b.is_full());
        // degenerate shapes must not panic
        let mut c = RowMask::new();
        c.fill_full(0, 0);
        assert_eq!(c.rows(), 0);
        assert!(!c.is_full());
    }

    #[test]
    fn rowmask_nbytes_tracks_selection() {
        let mut rng = Pcg32::seeded(52);
        let v = randn(&mut rng, &[4, 64]);
        let full = select_rowmask(&v, 0.0);
        let half = select_rowmask(&v, 0.5);
        let word = std::mem::size_of::<usize>();
        // keep-all is implicit: one shared 0..width row + one offset,
        // NOT rows * width indices (the fig6 gamma-0 baseline fix)
        assert_eq!(full.nbytes(), 4 * 64 + word);
        assert_eq!(half.nbytes(), 4 * half.selected() + word * 5);
        assert!(full.nbytes() < half.nbytes());
    }

    #[test]
    fn implicit_full_mask_serves_shared_row() {
        let mut rng = Pcg32::seeded(53);
        let v = randn(&mut rng, &[5, 17]);
        let full = select_rowmask(&v, 0.0);
        assert!(full.is_full());
        assert_eq!(full.selected(), 5 * 17);
        assert_eq!(full.density(), 1.0);
        let want: Vec<u32> = (0..17).collect();
        for i in 0..5 {
            assert_eq!(full.row(i), &want[..], "row {i}");
        }
        // an explicitly-constructed full selection canonicalizes to the
        // same implicit representation (so `==` keeps working)
        let dense = Tensor::full(&[5, 17], 1.0);
        assert_eq!(RowMask::from_dense(&dense), full);
        assert_eq!(full.to_dense(), dense);
    }

    #[test]
    fn rowmask_empty_rows_supported() {
        // a row where nothing passes the threshold has an empty list
        let v = Tensor::new(&[2, 3], vec![5.0, 6.0, 7.0, -1.0, -2.0, -3.0]);
        let rm = RowMask::from_threshold(&v, 0.0);
        assert_eq!(rm.row(0), &[0, 1, 2]);
        assert!(rm.row(1).is_empty());
        assert_eq!(rm.density(), 0.5);
        assert!(!rm.is_full());
    }
}
