//! Top-k selection with inter-sample threshold sharing (Appendix B,
//! Fig 9) and the three selection strategies of Fig 5(c).

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Graph-selection strategy (Fig 5c).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionStrategy {
    /// Dimension-reduction search: select on projected virtual activations.
    Drs,
    /// Oracle: select on the exact pre-activations (upper bound).
    Oracle,
    /// Random selection (lower bound).
    Random,
}

impl SelectionStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "drs" => Some(Self::Drs),
            "oracle" => Some(Self::Oracle),
            "random" => Some(Self::Random),
            _ => None,
        }
    }
}

/// The top-k threshold of sample 0, shared across the batch.
///
/// `virt` is (batch, width); gamma in [0, 1) is the target sparsity.
/// Returns -inf for gamma == 0 so every neuron of every sample is kept
/// (mirrors `compile/layers.py::shared_threshold`).
pub fn shared_threshold(virt: &Tensor, gamma: f32) -> f32 {
    assert!((0.0..1.0).contains(&gamma), "gamma out of range: {gamma}");
    let width = virt.shape()[1];
    let drop = ((gamma * width as f32).floor() as usize).min(width - 1);
    if drop == 0 {
        return f32::NEG_INFINITY;
    }
    let mut row0: Vec<f32> = virt.data()[..width].to_vec();
    // select_nth_unstable gives the ascending-order element at `drop` in
    // O(n) — cheaper than the full sort the HLO path uses.
    let (_, nth, _) = row0.select_nth_unstable_by(drop, |a, b| a.total_cmp(b));
    *nth
}

/// Binary selection mask for a (batch, width) virtual-activation matrix.
pub fn select_mask(
    virt: &Tensor,
    gamma: f32,
    strategy: SelectionStrategy,
    rng: &mut Pcg32,
) -> Tensor {
    let (batch, width) = (virt.shape()[0], virt.shape()[1]);
    match strategy {
        SelectionStrategy::Drs | SelectionStrategy::Oracle => {
            let t = shared_threshold(virt, gamma);
            Tensor::from_fn(&[batch, width], |i| {
                if virt.data()[i] >= t {
                    1.0
                } else {
                    0.0
                }
            })
        }
        SelectionStrategy::Random => {
            // keep ceil((1-gamma)*width) random neurons per sample
            let keep = width - ((gamma * width as f32).floor() as usize).min(width - 1);
            let mut mask = vec![0.0f32; batch * width];
            let mut idx: Vec<usize> = (0..width).collect();
            for b in 0..batch {
                rng.shuffle(&mut idx);
                for &j in idx.iter().take(keep) {
                    mask[b * width + j] = 1.0;
                }
            }
            Tensor::new(&[batch, width], mask)
        }
    }
}

/// Mask density (fraction of ones) — the measured 1-gamma.
pub fn mask_density(mask: &Tensor) -> f64 {
    if mask.is_empty() {
        return 0.0;
    }
    mask.data().iter().filter(|&&v| v != 0.0).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn gamma_zero_keeps_everything() {
        let mut rng = Pcg32::seeded(41);
        let v = randn(&mut rng, &[8, 100]);
        let m = select_mask(&v, 0.0, SelectionStrategy::Drs, &mut rng);
        assert_eq!(mask_density(&m), 1.0);
    }

    #[test]
    fn sample0_density_is_exact() {
        let mut rng = Pcg32::seeded(42);
        let v = randn(&mut rng, &[4, 1000]);
        for &g in &[0.3f32, 0.5, 0.8, 0.9] {
            let m = select_mask(&v, g, SelectionStrategy::Drs, &mut rng);
            let d0 = m.data()[..1000].iter().sum::<f32>() / 1000.0;
            let want = 1.0 - (g * 1000.0).floor() / 1000.0;
            assert!((d0 - want).abs() < 1e-6, "gamma {g}: {d0} vs {want}");
        }
    }

    #[test]
    fn shared_threshold_matches_sort() {
        let mut rng = Pcg32::seeded(43);
        let v = randn(&mut rng, &[2, 257]);
        let g = 0.7;
        let t = shared_threshold(&v, g);
        let mut row0: Vec<f32> = v.data()[..257].to_vec();
        row0.sort_by(|a, b| a.total_cmp(b));
        let drop = (g * 257.0).floor() as usize;
        assert_eq!(t, row0[drop]);
    }

    #[test]
    fn other_samples_share_threshold() {
        let mut rng = Pcg32::seeded(44);
        let v = randn(&mut rng, &[64, 500]);
        let m = select_mask(&v, 0.6, SelectionStrategy::Drs, &mut rng);
        let avg = mask_density(&m);
        assert!((avg - 0.4).abs() < 0.05, "avg density {avg}");
    }

    #[test]
    fn random_strategy_exact_per_sample() {
        let mut rng = Pcg32::seeded(45);
        let v = randn(&mut rng, &[16, 200]);
        let m = select_mask(&v, 0.75, SelectionStrategy::Random, &mut rng);
        for b in 0..16 {
            let kept: f32 = m.data()[b * 200..(b + 1) * 200].iter().sum();
            assert_eq!(kept, 50.0);
        }
    }

    #[test]
    fn oracle_keeps_true_top() {
        let v = Tensor::new(&[1, 4], vec![0.1, 5.0, -1.0, 3.0]);
        let m = select_mask(&v, 0.5, SelectionStrategy::Oracle, &mut Pcg32::seeded(1));
        assert_eq!(m.data(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(SelectionStrategy::parse("drs"), Some(SelectionStrategy::Drs));
        assert_eq!(SelectionStrategy::parse("oracle"), Some(SelectionStrategy::Oracle));
        assert_eq!(SelectionStrategy::parse("nope"), None);
    }

    #[test]
    #[should_panic]
    fn gamma_one_panics() {
        let v = Tensor::zeros(&[1, 4]);
        shared_threshold(&v, 1.0);
    }
}
