//! Top-k selection with inter-sample threshold sharing (Appendix B,
//! Fig 9), the three selection strategies of Fig 5(c), and the
//! structured (constant fan-in) selection mode: exact per-row top-k
//! with a fixed k per row, packed into the [`RowMask`] `FixedK` layout
//! that the packed-gather kernels in `sparse::parallel` exploit.
//!
//! DETERMINISTIC TIE-BREAKING: structured selection ranks entries by
//! `(value descending, index ascending)` — a strict total order, so the
//! selected top-k SET is unique even when scores tie.  Equal scores
//! resolve to the LOWEST indices, independent of partitioning internals,
//! thread budget, or repetition.  That is what makes structured masks
//! reproducible across runs (tested in
//! `structured_tie_break_is_ascending_index` below and in
//! `tests/pool_rowmask.rs`).

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// How the DRS turns virtual activations into a selection mask.
///
/// * `Unstructured` — the paper's scheme: one shared threshold from
///   sample 0, every entry `>= t` kept, variable row lengths (CSR).
/// * `Structured` — constant fan-in (Lasby et al.): exact per-row top-k
///   at the k matching the unstructured keep rate, every row exactly k
///   wide, packed `FixedK` layout.  `blocked` rounds k up to the 4-lane
///   accumulation block so packed rows align with `vmm_dot`'s grouping.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SelectionMode {
    #[default]
    Unstructured,
    Structured {
        blocked: bool,
    },
}

impl SelectionMode {
    /// Parse the `--selection` CLI forms:
    /// `unstructured | structured | structured:blocked`.
    pub fn parse(s: &str) -> Option<SelectionMode> {
        match s {
            "unstructured" => Some(SelectionMode::Unstructured),
            "structured" => Some(SelectionMode::Structured { blocked: false }),
            "structured:blocked" => Some(SelectionMode::Structured { blocked: true }),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SelectionMode::Unstructured => "unstructured",
            SelectionMode::Structured { blocked: false } => "structured",
            SelectionMode::Structured { blocked: true } => "structured:blocked",
        }
    }
}

/// The percentile core every threshold variant delegates to: the
/// ascending-order element at `floor(gamma * len)` of `pool`, selected
/// in O(n) via `select_nth_unstable` into a caller-owned scratch.
/// Returns -inf (keep-all) for an empty pool or a drop count of 0, and
/// clamps the drop index to `len - 1` so gamma close to 1 still keeps
/// at least one entry.
pub fn pool_threshold(pool: &[f32], gamma: f32, scratch: &mut Vec<f32>) -> f32 {
    assert!((0.0..1.0).contains(&gamma), "gamma out of range: {gamma}");
    if pool.is_empty() {
        return f32::NEG_INFINITY;
    }
    let drop = ((gamma * pool.len() as f32).floor() as usize).min(pool.len() - 1);
    if drop == 0 {
        return f32::NEG_INFINITY;
    }
    scratch.clear();
    scratch.extend_from_slice(pool);
    let (_, nth, _) = scratch.select_nth_unstable_by(drop, |a, b| a.total_cmp(b));
    *nth
}

/// Constant fan-in for a structured selection at sparsity `gamma`:
/// `width - drop` with the SAME drop rule as the unstructured threshold
/// (`floor(gamma * width)` clamped to `width - 1`), so both modes target
/// the same keep rate at matched gamma.  `blocked` rounds k UP to the
/// next multiple of 4 — the `vmm_dot` accumulation block — capped at
/// `width`.  Always >= 1 for a nonzero width; gamma = 0 gives
/// `k == width` (keep-all).
pub fn structured_k(width: usize, gamma: f32, blocked: bool) -> usize {
    assert!((0.0..1.0).contains(&gamma), "gamma out of range: {gamma}");
    if width == 0 {
        return 0;
    }
    let drop = ((gamma * width as f32).floor() as usize).min(width - 1);
    let k = width - drop;
    if blocked {
        ((k + 3) & !3usize).min(width)
    } else {
        k
    }
}

/// Graph-selection strategy (Fig 5c).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionStrategy {
    /// Dimension-reduction search: select on projected virtual activations.
    Drs,
    /// Oracle: select on the exact pre-activations (upper bound).
    Oracle,
    /// Random selection (lower bound).
    Random,
}

impl SelectionStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "drs" => Some(Self::Drs),
            "oracle" => Some(Self::Oracle),
            "random" => Some(Self::Random),
            _ => None,
        }
    }
}

/// The top-k threshold of sample 0, shared across the batch.
///
/// `virt` is (batch, width); gamma in [0, 1) is the target sparsity.
/// Returns -inf for gamma == 0 so every neuron of every sample is kept
/// (mirrors `compile/layers.py::shared_threshold`).
pub fn shared_threshold(virt: &Tensor, gamma: f32) -> f32 {
    shared_threshold_scratch(virt, gamma, &mut Vec::new())
}

/// `shared_threshold` selecting from a caller-owned scratch buffer, so
/// the per-layer `to_vec` copy disappears in steady state (the buffer is
/// cleared and refilled, reusing its capacity).
pub fn shared_threshold_scratch(virt: &Tensor, gamma: f32, scratch: &mut Vec<f32>) -> f32 {
    shared_threshold_slice(virt.data(), virt.shape()[1], gamma, scratch)
}

/// Slice form of [`shared_threshold_scratch`]: `virt` is row-major
/// (batch, width) and only row 0 is consulted.  A zero-width layer has
/// nothing to rank, so the threshold degrades to keep-all (-inf) instead
/// of underflowing `width - 1`.  Thin wrapper over [`pool_threshold`]
/// with row 0 as the pool (the conv path passes a larger pool — all of
/// sample 0's spatial positions — through `pool_threshold` directly).
pub fn shared_threshold_slice(
    virt: &[f32],
    width: usize,
    gamma: f32,
    scratch: &mut Vec<f32>,
) -> f32 {
    pool_threshold(&virt[..width], gamma, scratch)
}

/// Compact selection mask: per-row selected-index lists in CSR form.
///
/// The dense f32 mask costs O(m*n) memory and forces the masked VMM to
/// branch-scan all n columns per row; this stores only the selected
/// indices (the paper's §3 memory argument applied to our own engine)
/// and lets the VMM jump straight to the selected neurons.  Indices are
/// ascending within a row, so engines visiting them reproduce the
/// dense-mask scan order bit-for-bit.
///
/// A keep-all mask (gamma = 0 / dense mode) is IMPLICIT: it stores one
/// shared `0..width` index row that [`RowMask::row`] serves for every
/// row, instead of materializing `rows * width` u32 indices.  Every
/// constructor canonicalizes to this form whenever the selection turns
/// out to be full, so structural equality (`==`) keeps working and
/// [`RowMask::nbytes`] — and with it the training-tape
/// [`crate::metrics::MemoryMeter`] accounting — charges O(width), not
/// O(rows * width), for the gamma-0 baseline.
///
/// LAYOUTS.  The mask is layout-aware:
///
/// * CSR (default): `offsets` holds rows + 1 cursor positions into
///   `idx`, rows have variable lengths — what unstructured threshold
///   selection produces.
/// * `FixedK` ([`RowMask::fill_topk`]): every row holds EXACTLY
///   `k` indices, `idx` is one contiguous rows x k matrix, `offsets` is
///   empty — row i lives at `idx[i*k .. (i+1)*k]` with no offsets load
///   (O(1) row addressing), and [`RowMask::nbytes`] charges exactly
///   `4 * rows * k` (no offsets term).  The packed-gather kernels in
///   `sparse::parallel` key off [`RowMask::packed`] to run fixed trip
///   counts with no per-row length branches.
///
/// Consumers that only read `row(i)` / `selected()` / `is_full()` are
/// layout-agnostic: a `FixedK` mask serves the same ascending per-row
/// index slices through the same API, so the CSR kernels remain valid
/// (and bit-identical) baselines on a packed selection.
#[derive(Clone, Debug, PartialEq)]
pub struct RowMask {
    rows: usize,
    width: usize,
    /// Canonical keep-all flag: `idx` holds ONE shared `0..width` row
    /// and `offsets` collapses to `[0]`.
    full: bool,
    /// Packed constant fan-in layout: every row has exactly k entries at
    /// `idx[i*k..(i+1)*k]`, `offsets` is empty.  `None` = CSR or full.
    fixed_k: Option<usize>,
    /// rows + 1 offsets into `idx` (just `[0]` when `full`, empty when
    /// `fixed_k` is set).
    offsets: Vec<usize>,
    /// Selected column indices, ascending within each row (the shared
    /// `0..width` row when `full`).
    idx: Vec<u32>,
}

impl Default for RowMask {
    fn default() -> Self {
        RowMask::new()
    }
}

impl RowMask {
    /// An empty 0 x 0 mask (workspace placeholder; fill before use).
    pub fn new() -> RowMask {
        RowMask {
            rows: 0,
            width: 0,
            full: false,
            fixed_k: None,
            offsets: vec![0],
            idx: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Selected column indices of row `i` (ascending).  A full mask
    /// serves the one shared `0..width` row for every `i`; a `FixedK`
    /// mask addresses its packed matrix directly (no offsets load).
    pub fn row(&self, i: usize) -> &[u32] {
        if self.full {
            debug_assert!(i < self.rows);
            return &self.idx;
        }
        if let Some(k) = self.fixed_k {
            return &self.idx[i * k..(i + 1) * k];
        }
        &self.idx[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Constant fan-in of a `FixedK` mask (`None` for CSR / keep-all).
    pub fn fixed_k(&self) -> Option<usize> {
        self.fixed_k
    }

    /// Packed view of a `FixedK` mask: `(idx, k)` with row i at
    /// `idx[i*k..(i+1)*k]` — what the packed-gather kernels consume.
    /// `None` for CSR and canonical keep-all masks.
    pub fn packed(&self) -> Option<(&[u32], usize)> {
        self.fixed_k.map(|k| (&self.idx[..], k))
    }

    /// Total selected entries.
    pub fn selected(&self) -> usize {
        if self.full {
            return self.rows * self.width;
        }
        self.idx.len()
    }

    /// Canonicalize a fully-selected explicit mask into the implicit
    /// keep-all form: keep the first row's `0..width` indices as the
    /// shared row, drop the per-row storage.
    fn canonicalize_full(&mut self) {
        debug_assert!(self.fixed_k.is_none(), "canonicalize_full on a packed mask");
        if !self.full && self.rows * self.width > 0 && self.idx.len() == self.rows * self.width {
            self.full = true;
            self.idx.truncate(self.width); // row 0 IS 0..width when full
            self.offsets.clear();
            self.offsets.push(0);
        }
    }

    /// Heap bytes this mask holds (index list + offsets) — what the
    /// training-tape [`crate::metrics::MemoryMeter`] charges for the
    /// taped selection, the measured twin of the paper's "mask
    /// overhead" term in `memmodel`.  A `FixedK` mask has no offsets
    /// array, so it is charged at its packed size: exactly
    /// `4 * rows * k` bytes.
    pub fn nbytes(&self) -> usize {
        4 * self.idx.len() + std::mem::size_of::<usize>() * self.offsets.len()
    }

    /// Fraction of selected entries — the measured 1-gamma.
    pub fn density(&self) -> f64 {
        let total = self.rows * self.width;
        if total == 0 {
            return 0.0;
        }
        self.selected() as f64 / total as f64
    }

    /// True when every entry is selected (gamma = 0 keep-all): engines
    /// take a dense fast path with no index indirection.
    pub fn is_full(&self) -> bool {
        self.full
    }

    /// Rebuild in place from row-major virtual activations and a shared
    /// threshold, reusing the index storage (allocation-free once warm).
    pub fn fill_from_threshold(&mut self, virt: &[f32], rows: usize, width: usize, t: f32) {
        debug_assert_eq!(virt.len(), rows * width);
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        if t == f32::NEG_INFINITY {
            // keep-all threshold: every finite (and NaN-free) activation
            // passes `v >= -inf`, so skip the scan and go straight to
            // the implicit form.  NaN virt entries would fail the
            // comparison, but a NaN virtual activation means the run is
            // already lost — selection shape is the least of it.
            self.fill_full(rows, width);
            return;
        }
        self.full = false;
        self.fixed_k = None;
        self.rows = rows;
        self.width = width;
        self.offsets.clear();
        self.offsets.reserve(rows + 1);
        self.offsets.push(0);
        self.idx.clear();
        for i in 0..rows {
            let vrow = &virt[i * width..(i + 1) * width];
            for (j, &v) in vrow.iter().enumerate() {
                if v >= t {
                    self.idx.push(j as u32);
                }
            }
            self.offsets.push(self.idx.len());
        }
        self.canonicalize_full();
    }

    /// Rebuild in place as the keep-all mask (every column of every row
    /// selected) — equal to `fill_from_threshold` with a -inf threshold,
    /// without needing virtual activations (the dense-mode training
    /// path).  Stores one shared `0..width` row, NOT rows * width
    /// indices.
    pub fn fill_full(&mut self, rows: usize, width: usize) {
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        self.rows = rows;
        self.width = width;
        self.fixed_k = None;
        self.idx.clear();
        self.offsets.clear();
        if rows * width > 0 {
            self.full = true;
            self.idx.extend(0..width as u32);
            self.offsets.push(0);
        } else {
            // degenerate shape: empty explicit mask so `row(i)` still
            // works for width-0 rows
            self.full = false;
            self.offsets.resize(rows + 1, 0);
        }
    }

    /// Rebuild in place as a STRUCTURED (constant fan-in) selection:
    /// exact per-row top-k over row-major virtual activations, packed
    /// into the `FixedK` layout.  Ranking is by `(value descending,
    /// index ascending)` — a strict total order, so equal scores resolve
    /// deterministically to the LOWEST indices (reproducible across
    /// runs and thread budgets); the stored row is then sorted to the
    /// ascending-index order every kernel's accumulation contract
    /// requires.  `k == width` canonicalizes to the implicit keep-all
    /// form, making gamma = 0 structured selection bit-equal to the
    /// unstructured keep-all path.  `scratch` is a caller-owned
    /// (value, index) ranking buffer, reused across rows and layers.
    pub fn fill_topk(
        &mut self,
        virt: &[f32],
        rows: usize,
        width: usize,
        k: usize,
        scratch: &mut Vec<(f32, u32)>,
    ) {
        debug_assert_eq!(virt.len(), rows * width);
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        assert!(k <= width, "fan-in {k} exceeds width {width}");
        if k == width {
            // keep-all: identical canonical form (and bits) to the
            // unstructured -inf-threshold path
            self.fill_full(rows, width);
            return;
        }
        self.full = false;
        self.fixed_k = Some(k);
        self.rows = rows;
        self.width = width;
        self.offsets.clear();
        self.idx.clear();
        self.idx.reserve(rows * k);
        if k == 0 {
            return; // every row is an empty slice of the packed matrix
        }
        for vrow in virt.chunks_exact(width) {
            scratch.clear();
            scratch.extend(vrow.iter().enumerate().map(|(j, &v)| (v, j as u32)));
            // the top-k SET under this total order is unique, so the
            // unstable partition cannot leak nondeterminism
            scratch.select_nth_unstable_by(k - 1, |a, b| {
                b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1))
            });
            let row_start = self.idx.len();
            self.idx.extend(scratch[..k].iter().map(|&(_, j)| j));
            self.idx[row_start..].sort_unstable();
        }
    }

    /// Re-express this selection in explicit CSR form (same rows, same
    /// ascending indices, offsets materialized).  Used by parity tests
    /// and benches to run the CSR kernels against a packed selection;
    /// a fully-selected input canonicalizes to keep-all as usual.
    pub fn to_csr(&self) -> RowMask {
        let mut m = RowMask::new();
        m.rows = self.rows;
        m.width = self.width;
        m.offsets.clear();
        m.offsets.reserve(self.rows + 1);
        m.offsets.push(0);
        m.idx.reserve(self.selected());
        for i in 0..self.rows {
            m.idx.extend_from_slice(self.row(i));
            m.offsets.push(m.idx.len());
        }
        m.canonicalize_full();
        m
    }

    /// Build from a (rows, width) virtual-activation tensor + threshold.
    pub fn from_threshold(virt: &Tensor, t: f32) -> RowMask {
        let mut m = RowMask::new();
        m.fill_from_threshold(virt.data(), virt.shape()[0], virt.shape()[1], t);
        m
    }

    /// Build from a dense (rows, width) 0/1 mask (nonzero = selected).
    pub fn from_dense(mask: &Tensor) -> RowMask {
        let (rows, width) = (mask.shape()[0], mask.shape()[1]);
        assert!(width <= u32::MAX as usize, "mask width {width} exceeds u32");
        let mut m = RowMask::new();
        m.rows = rows;
        m.width = width;
        m.offsets.clear();
        m.offsets.push(0);
        for i in 0..rows {
            let mrow = &mask.data()[i * width..(i + 1) * width];
            for (j, &v) in mrow.iter().enumerate() {
                if v != 0.0 {
                    m.idx.push(j as u32);
                }
            }
            m.offsets.push(m.idx.len());
        }
        m.canonicalize_full();
        m
    }

    /// Expand to a dense 0/1 f32 mask (tests / compat).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.width];
        for i in 0..self.rows {
            for &j in self.row(i) {
                out[i * self.width + j as usize] = 1.0;
            }
        }
        Tensor::new(&[self.rows, self.width], out)
    }
}

/// DRS selection as a compact [`RowMask`]: shared threshold from sample
/// 0, selection over the whole batch.
pub fn select_rowmask(virt: &Tensor, gamma: f32) -> RowMask {
    let t = shared_threshold(virt, gamma);
    RowMask::from_threshold(virt, t)
}

/// STRUCTURED DRS selection as a packed [`RowMask`]: exact per-row
/// top-[`structured_k`] at matched gamma (constant fan-in), `FixedK`
/// layout.  `blocked` rounds k up to the 4-lane accumulation block.
pub fn select_structured(virt: &Tensor, gamma: f32, blocked: bool) -> RowMask {
    let (rows, width) = (virt.shape()[0], virt.shape()[1]);
    let mut m = RowMask::new();
    m.fill_topk(
        virt.data(),
        rows,
        width,
        structured_k(width, gamma, blocked),
        &mut Vec::new(),
    );
    m
}

/// Binary selection mask for a (batch, width) virtual-activation matrix.
pub fn select_mask(
    virt: &Tensor,
    gamma: f32,
    strategy: SelectionStrategy,
    rng: &mut Pcg32,
) -> Tensor {
    let (batch, width) = (virt.shape()[0], virt.shape()[1]);
    match strategy {
        SelectionStrategy::Drs | SelectionStrategy::Oracle => {
            let t = shared_threshold(virt, gamma);
            Tensor::from_fn(&[batch, width], |i| {
                if virt.data()[i] >= t {
                    1.0
                } else {
                    0.0
                }
            })
        }
        SelectionStrategy::Random => {
            // keep ceil((1-gamma)*width) random neurons per sample
            let keep = width - ((gamma * width as f32).floor() as usize).min(width - 1);
            let mut mask = vec![0.0f32; batch * width];
            let mut idx: Vec<usize> = (0..width).collect();
            for b in 0..batch {
                rng.shuffle(&mut idx);
                for &j in idx.iter().take(keep) {
                    mask[b * width + j] = 1.0;
                }
            }
            Tensor::new(&[batch, width], mask)
        }
    }
}

/// Mask density (fraction of ones) — the measured 1-gamma.
pub fn mask_density(mask: &Tensor) -> f64 {
    if mask.is_empty() {
        return 0.0;
    }
    mask.data().iter().filter(|&&v| v != 0.0).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn gamma_zero_keeps_everything() {
        let mut rng = Pcg32::seeded(41);
        let v = randn(&mut rng, &[8, 100]);
        let m = select_mask(&v, 0.0, SelectionStrategy::Drs, &mut rng);
        assert_eq!(mask_density(&m), 1.0);
    }

    #[test]
    fn sample0_density_is_exact() {
        let mut rng = Pcg32::seeded(42);
        let v = randn(&mut rng, &[4, 1000]);
        for &g in &[0.3f32, 0.5, 0.8, 0.9] {
            let m = select_mask(&v, g, SelectionStrategy::Drs, &mut rng);
            let d0 = m.data()[..1000].iter().sum::<f32>() / 1000.0;
            let want = 1.0 - (g * 1000.0).floor() / 1000.0;
            assert!((d0 - want).abs() < 1e-6, "gamma {g}: {d0} vs {want}");
        }
    }

    #[test]
    fn shared_threshold_matches_sort() {
        let mut rng = Pcg32::seeded(43);
        let v = randn(&mut rng, &[2, 257]);
        let g = 0.7;
        let t = shared_threshold(&v, g);
        let mut row0: Vec<f32> = v.data()[..257].to_vec();
        row0.sort_by(|a, b| a.total_cmp(b));
        let drop = (g * 257.0).floor() as usize;
        assert_eq!(t, row0[drop]);
    }

    #[test]
    fn other_samples_share_threshold() {
        let mut rng = Pcg32::seeded(44);
        let v = randn(&mut rng, &[64, 500]);
        let m = select_mask(&v, 0.6, SelectionStrategy::Drs, &mut rng);
        let avg = mask_density(&m);
        assert!((avg - 0.4).abs() < 0.05, "avg density {avg}");
    }

    #[test]
    fn random_strategy_exact_per_sample() {
        let mut rng = Pcg32::seeded(45);
        let v = randn(&mut rng, &[16, 200]);
        let m = select_mask(&v, 0.75, SelectionStrategy::Random, &mut rng);
        for b in 0..16 {
            let kept: f32 = m.data()[b * 200..(b + 1) * 200].iter().sum();
            assert_eq!(kept, 50.0);
        }
    }

    #[test]
    fn oracle_keeps_true_top() {
        let v = Tensor::new(&[1, 4], vec![0.1, 5.0, -1.0, 3.0]);
        let m = select_mask(&v, 0.5, SelectionStrategy::Oracle, &mut Pcg32::seeded(1));
        assert_eq!(m.data(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(SelectionStrategy::parse("drs"), Some(SelectionStrategy::Drs));
        assert_eq!(SelectionStrategy::parse("oracle"), Some(SelectionStrategy::Oracle));
        assert_eq!(SelectionStrategy::parse("nope"), None);
    }

    #[test]
    #[should_panic]
    fn gamma_one_panics() {
        let v = Tensor::zeros(&[1, 4]);
        shared_threshold(&v, 1.0);
    }

    #[test]
    fn scratch_threshold_matches_plain() {
        let mut rng = Pcg32::seeded(46);
        let v = randn(&mut rng, &[4, 300]);
        let mut scratch = Vec::new();
        for &g in &[0.0f32, 0.3, 0.8, 0.95] {
            assert_eq!(
                shared_threshold(&v, g),
                shared_threshold_scratch(&v, g, &mut scratch),
                "gamma {g}"
            );
        }
    }

    #[test]
    fn rowmask_roundtrips_dense() {
        let mut rng = Pcg32::seeded(47);
        let v = randn(&mut rng, &[6, 40]);
        let dense = select_mask(&v, 0.6, SelectionStrategy::Drs, &mut rng);
        let rm = RowMask::from_dense(&dense);
        assert_eq!(rm.to_dense(), dense);
        assert_eq!(rm.density(), mask_density(&dense));
        // from_threshold agrees with the dense construction
        let t = shared_threshold(&v, 0.6);
        assert_eq!(RowMask::from_threshold(&v, t), rm);
        assert_eq!(select_rowmask(&v, 0.6), rm);
    }

    #[test]
    fn rowmask_rows_are_ascending() {
        let mut rng = Pcg32::seeded(48);
        let v = randn(&mut rng, &[5, 64]);
        let rm = select_rowmask(&v, 0.7);
        for i in 0..rm.rows() {
            let r = rm.row(i);
            for w in r.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        assert_eq!(
            rm.selected(),
            (0..rm.rows()).map(|i| rm.row(i).len()).sum::<usize>()
        );
    }

    #[test]
    fn rowmask_keep_all_is_full() {
        let mut rng = Pcg32::seeded(49);
        let v = randn(&mut rng, &[3, 32]);
        let rm = select_rowmask(&v, 0.0);
        assert!(rm.is_full());
        assert_eq!(rm.density(), 1.0);
        let partial = select_rowmask(&v, 0.5);
        assert!(!partial.is_full());
    }

    #[test]
    fn rowmask_fill_reuses_storage() {
        let mut rng = Pcg32::seeded(50);
        let v = randn(&mut rng, &[8, 128]);
        let t = shared_threshold(&v, 0.8);
        let mut rm = RowMask::new();
        rm.fill_from_threshold(v.data(), 8, 128, t);
        let first = rm.clone();
        // refill with a different shape, then back: same result
        rm.fill_from_threshold(&v.data()[..4 * 128], 4, 128, t);
        rm.fill_from_threshold(v.data(), 8, 128, t);
        assert_eq!(rm, first);
    }

    #[test]
    fn zero_width_threshold_keeps_all() {
        let mut scratch = Vec::new();
        for &g in &[0.0f32, 0.5, 0.99] {
            assert_eq!(
                shared_threshold_slice(&[], 0, g, &mut scratch),
                f32::NEG_INFINITY,
                "gamma {g}"
            );
        }
    }

    #[test]
    fn fill_full_matches_neg_inf_threshold() {
        let mut rng = Pcg32::seeded(51);
        let v = randn(&mut rng, &[4, 9]);
        let mut a = RowMask::new();
        a.fill_from_threshold(v.data(), 4, 9, f32::NEG_INFINITY);
        let mut b = RowMask::new();
        b.fill_full(4, 9);
        assert_eq!(a, b);
        assert!(b.is_full());
        // degenerate shapes must not panic
        let mut c = RowMask::new();
        c.fill_full(0, 0);
        assert_eq!(c.rows(), 0);
        assert!(!c.is_full());
    }

    #[test]
    fn rowmask_nbytes_tracks_selection() {
        let mut rng = Pcg32::seeded(52);
        let v = randn(&mut rng, &[4, 64]);
        let full = select_rowmask(&v, 0.0);
        let half = select_rowmask(&v, 0.5);
        let word = std::mem::size_of::<usize>();
        // keep-all is implicit: one shared 0..width row + one offset,
        // NOT rows * width indices (the fig6 gamma-0 baseline fix)
        assert_eq!(full.nbytes(), 4 * 64 + word);
        assert_eq!(half.nbytes(), 4 * half.selected() + word * 5);
        assert!(full.nbytes() < half.nbytes());
    }

    #[test]
    fn implicit_full_mask_serves_shared_row() {
        let mut rng = Pcg32::seeded(53);
        let v = randn(&mut rng, &[5, 17]);
        let full = select_rowmask(&v, 0.0);
        assert!(full.is_full());
        assert_eq!(full.selected(), 5 * 17);
        assert_eq!(full.density(), 1.0);
        let want: Vec<u32> = (0..17).collect();
        for i in 0..5 {
            assert_eq!(full.row(i), &want[..], "row {i}");
        }
        // an explicitly-constructed full selection canonicalizes to the
        // same implicit representation (so `==` keeps working)
        let dense = Tensor::full(&[5, 17], 1.0);
        assert_eq!(RowMask::from_dense(&dense), full);
        assert_eq!(full.to_dense(), dense);
    }

    #[test]
    fn selection_mode_parse_and_label() {
        assert_eq!(SelectionMode::parse("unstructured"), Some(SelectionMode::Unstructured));
        assert_eq!(
            SelectionMode::parse("structured"),
            Some(SelectionMode::Structured { blocked: false })
        );
        assert_eq!(
            SelectionMode::parse("structured:blocked"),
            Some(SelectionMode::Structured { blocked: true })
        );
        assert_eq!(SelectionMode::parse("csr"), None);
        assert_eq!(SelectionMode::default(), SelectionMode::Unstructured);
        for s in ["unstructured", "structured", "structured:blocked"] {
            assert_eq!(SelectionMode::parse(s).unwrap().label(), s);
        }
    }

    #[test]
    fn pool_threshold_consolidates_all_wrappers() {
        // satellite: one percentile core — the tensor, scratch, and
        // slice wrappers must all agree with a direct pool call
        let mut rng = Pcg32::seeded(58);
        let v = randn(&mut rng, &[3, 200]);
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        for &g in &[0.0f32, 0.4, 0.85] {
            let want = pool_threshold(&v.data()[..200], g, &mut s1);
            assert_eq!(want, shared_threshold(&v, g), "gamma {g}");
            assert_eq!(want, shared_threshold_scratch(&v, g, &mut s2), "gamma {g}");
            assert_eq!(want, shared_threshold_slice(v.data(), 200, g, &mut s2), "gamma {g}");
        }
        assert_eq!(pool_threshold(&[], 0.5, &mut s1), f32::NEG_INFINITY);
    }

    #[test]
    fn structured_k_tracks_unstructured_keep_rate() {
        for width in [1usize, 3, 7, 64, 257] {
            for &g in &[0.0f32, 0.3, 0.5, 0.9, 0.99] {
                let drop = ((g * width as f32).floor() as usize).min(width - 1);
                let k = structured_k(width, g, false);
                assert_eq!(k, width - drop, "width {width} gamma {g}");
                assert!(k >= 1);
                let kb = structured_k(width, g, true);
                assert!(kb >= k && kb <= width);
                assert!(kb % 4 == 0 || kb == width, "blocked k {kb} width {width}");
            }
        }
        assert_eq!(structured_k(0, 0.5, false), 0);
        assert_eq!(structured_k(64, 0.0, true), 64); // keep-all stays exact
    }

    #[test]
    fn structured_selection_is_exact_per_row_topk() {
        let mut rng = Pcg32::seeded(59);
        let v = randn(&mut rng, &[9, 57]);
        let gamma = 0.7;
        let rm = select_structured(&v, gamma, false);
        let k = structured_k(57, gamma, false);
        assert_eq!(rm.fixed_k(), Some(k));
        for i in 0..9 {
            let sel = rm.row(i);
            assert_eq!(sel.len(), k, "row {i}");
            for w in sel.windows(2) {
                assert!(w[0] < w[1], "row {i} not ascending");
            }
            // every selected value >= every unselected value
            let vrow = &v.data()[i * 57..(i + 1) * 57];
            let min_sel = sel.iter().map(|&j| vrow[j as usize]).fold(f32::INFINITY, f32::min);
            for j in 0..57u32 {
                if !sel.contains(&j) {
                    assert!(vrow[j as usize] <= min_sel, "row {i} col {j}");
                }
            }
        }
        assert_eq!(rm.selected(), 9 * k);
        assert_eq!((rm.density() * 57.0).round() as usize, k);
    }

    #[test]
    fn structured_tie_break_is_ascending_index() {
        // four-way tie at the cut: the LOWEST indices must win, and
        // repeated selection must be identical (reproducibility)
        let v = Tensor::new(&[2, 6], vec![
            1.0, 5.0, 1.0, 1.0, 1.0, 0.0, // row 0: tie among cols 0,2,3,4
            2.0, 2.0, 2.0, 2.0, 2.0, 2.0, // row 1: everything ties
        ]);
        let mut rm = RowMask::new();
        let mut scratch = Vec::new();
        rm.fill_topk(v.data(), 2, 6, 3, &mut scratch);
        assert_eq!(rm.row(0), &[0, 1, 2]);
        assert_eq!(rm.row(1), &[0, 1, 2]);
        let again = {
            let mut m = RowMask::new();
            m.fill_topk(v.data(), 2, 6, 3, &mut scratch);
            m
        };
        assert_eq!(rm, again);
    }

    #[test]
    fn structured_k_width_canonicalizes_to_keep_all() {
        let mut rng = Pcg32::seeded(60);
        let v = randn(&mut rng, &[5, 24]);
        let rm = select_structured(&v, 0.0, false);
        assert!(rm.is_full());
        assert_eq!(rm.fixed_k(), None);
        assert!(rm.packed().is_none());
        // bit-equal (structurally equal) to the unstructured keep-all
        assert_eq!(rm, select_rowmask(&v, 0.0));
    }

    #[test]
    fn fixedk_nbytes_is_packed_size() {
        let mut rng = Pcg32::seeded(61);
        let v = randn(&mut rng, &[8, 40]);
        let rm = select_structured(&v, 0.6, false);
        let k = rm.fixed_k().unwrap();
        // packed accounting: rows * k indices, NO offsets array
        assert_eq!(rm.nbytes(), 4 * 8 * k);
        let csr = rm.to_csr();
        assert_eq!(csr.fixed_k(), None);
        assert_eq!(csr.selected(), rm.selected());
        for i in 0..8 {
            assert_eq!(csr.row(i), rm.row(i), "row {i}");
        }
        assert_eq!(csr.to_dense(), rm.to_dense());
        assert!(csr.nbytes() > rm.nbytes(), "CSR must pay for offsets");
        // k = 0 rows: legal, empty rows, zero index bytes
        let mut z = RowMask::new();
        z.fill_topk(v.data(), 8, 40, 0, &mut Vec::new());
        assert_eq!(z.fixed_k(), Some(0));
        assert_eq!(z.selected(), 0);
        assert_eq!(z.nbytes(), 0);
        assert!(z.row(3).is_empty());
    }

    #[test]
    fn blocked_structured_selection_aligns_rows() {
        let mut rng = Pcg32::seeded(62);
        let v = randn(&mut rng, &[6, 50]);
        let rm = select_structured(&v, 0.7, true);
        let k = rm.fixed_k().unwrap();
        assert_eq!(k % 4, 0);
        assert!(k >= structured_k(50, 0.7, false));
        for i in 0..6 {
            assert_eq!(rm.row(i).len(), k);
        }
    }

    #[test]
    fn rowmask_empty_rows_supported() {
        // a row where nothing passes the threshold has an empty list
        let v = Tensor::new(&[2, 3], vec![5.0, 6.0, 7.0, -1.0, -2.0, -3.0]);
        let rm = RowMask::from_threshold(&v, 0.0);
        assert_eq!(rm.row(0), &[0, 1, 2]);
        assert!(rm.row(1).is_empty());
        assert_eq!(rm.density(), 0.5);
        assert!(!rm.is_full());
    }
}
