//! Host-side dimension-reduction search (paper §2.1-2.2, Appendix B).
//!
//! This is the rust mirror of the L1/L2 DRS used for:
//!   * the CPU sparse execution engine (Fig 8) — here the vector-wise
//!     column skip actually pays off in wall-clock;
//!   * unit/property tests that cross-check the python semantics;
//!   * the selection-strategy baselines (oracle / random, Fig 5c).

pub mod projection;
pub mod topk;

pub use projection::{
    project_rows, project_rows_idx, project_weights, project_weights_idx, ternary_r,
};
pub use topk::{
    pool_threshold, select_mask, select_rowmask, select_structured, shared_threshold,
    structured_k, RowMask, SelectionMode, SelectionStrategy,
};
