//! Computational-cost model (§3.4, Fig 7, Table 1).
//!
//! Per maskable layer, DSG replaces the dense n_PQ*n_CRS*n_K MAC volume
//! with the §2.2 complexity  n_PQ * n_K * (k + (1-gamma) * n_CRS):
//! the low-dimensional search VMM plus the exact compute of only the
//! selected neurons.  Backward: the error propagation is accelerated by
//! the mask (factor 1-gamma) while the weight-gradient GEMM is counted
//! fully dense — the paper explicitly excludes its reduction "for
//! practical concern" (irregular sparsity).

pub mod jll;
pub mod shapes;

use shapes::{Layer, NetShape};

/// MAC accounting for one network at one sparsity level.
#[derive(Clone, Copy, Debug, Default)]
pub struct MacBreakdown {
    /// dense baseline forward MACs (per batch)
    pub fwd_dense: u64,
    /// DSG forward: search + selected exact compute
    pub fwd_dsg: u64,
    /// of which the dimension-reduction search (low-dim VMM)
    pub search: u64,
    /// dense baseline backward (error prop + weight grad)
    pub bwd_dense: u64,
    /// DSG backward (masked error prop + dense weight grad)
    pub bwd_dsg: u64,
}

impl MacBreakdown {
    pub fn train_dense(&self) -> u64 {
        self.fwd_dense + self.bwd_dense
    }
    pub fn train_dsg(&self) -> u64 {
        self.fwd_dsg + self.bwd_dsg
    }
    pub fn train_reduction(&self) -> f64 {
        self.train_dense() as f64 / self.train_dsg() as f64
    }
    pub fn infer_reduction(&self) -> f64 {
        self.fwd_dense as f64 / self.fwd_dsg as f64
    }
    /// DRS overhead relative to the DENSE baseline cost — this is the
    /// arithmetic under the paper's "<6.5% in training and <19.5% in
    /// inference": at eps=0.5 the search VMM costs k/n_CRS ~ 1/8.5 ~ 12-20%
    /// of one dense forward, which is ~1/3 of a dense training step.
    pub fn search_frac_train(&self) -> f64 {
        self.search as f64 / self.train_dense() as f64
    }
    pub fn search_frac_infer(&self) -> f64 {
        self.search as f64 / self.fwd_dense as f64
    }
}

/// Per-layer DSG forward MACs (per sample).
///
/// Layers too small for the JLL bound to reduce anything (k clipped to
/// ~d_in) do not run DRS — projecting would cost as much as computing
/// densely, so the layer stays dense (the paper's naive-selection
/// observation in §2: selection only pays when estimation is cheap).
pub fn layer_fwd_dsg(l: &Layer, gamma: f64, eps: f64) -> (u64, u64) {
    if !l.maskable {
        return (l.fwd_macs(), 0);
    }
    let k = jll::projection_dim(eps, l.n_k, l.n_crs);
    if k * 2 > l.n_crs {
        return (l.fwd_macs(), 0); // <2x reduction: search doesn't pay
    }
    let search = (l.n_pq * k * l.n_k) as u64;
    let exact = ((l.n_pq * l.n_crs * l.n_k) as f64 * (1.0 - gamma)) as u64;
    (search + exact, search)
}

/// Full-network MAC breakdown at (gamma, eps) for one mini-batch.
pub fn macs(net: &NetShape, gamma: f64, eps: f64) -> MacBreakdown {
    let b = net.batch as u64;
    let mut out = MacBreakdown::default();
    for l in &net.layers {
        let dense = l.fwd_macs();
        let (dsg, search) = layer_fwd_dsg(l, gamma, eps);
        out.fwd_dense += b * dense;
        out.fwd_dsg += b * dsg;
        out.search += b * search;
        // backward: error propagation + weight gradient, both ~= fwd cost
        out.bwd_dense += b * dense * 2;
        let err_dsg = if l.maskable {
            (dense as f64 * (1.0 - gamma)) as u64
        } else {
            dense
        };
        out.bwd_dsg += b * (err_dsg + dense); // wgrad counted dense
    }
    out
}

/// GMACs helper (1e9, as the paper reports).
pub fn gmacs(macs: u64) -> f64 {
    macs as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use shapes::fig6_nets;

    #[test]
    fn fig7_training_reduction_shape() {
        // Paper: 1.4x / 1.7x / 2.2x average training reduction at
        // 50/80/90% sparsity.  Check the averages land near those.
        let want = [(0.5, 1.4), (0.8, 1.7), (0.9, 2.2)];
        for (gamma, target) in want {
            let mut rs = Vec::new();
            for net in fig6_nets() {
                rs.push(macs(&net, gamma, 0.5).train_reduction());
            }
            let avg = rs.iter().sum::<f64>() / rs.len() as f64;
            assert!(
                (avg - target).abs() / target < 0.35,
                "gamma {gamma}: avg train reduction {avg:.2} vs paper {target}"
            );
        }
    }

    #[test]
    fn fig7_inference_reduction_shape() {
        // Paper: 1.5x / 2.8x / 3.9x at 50/80/90%.
        let want = [(0.5, 1.5), (0.8, 2.8), (0.9, 3.9)];
        for (gamma, target) in want {
            let mut rs = Vec::new();
            for net in fig6_nets() {
                rs.push(macs(&net, gamma, 0.5).infer_reduction());
            }
            let avg = rs.iter().sum::<f64>() / rs.len() as f64;
            assert!(
                (avg - target).abs() / target < 0.35,
                "gamma {gamma}: avg infer reduction {avg:.2} vs paper {target}"
            );
        }
    }

    #[test]
    fn search_overhead_bounds() {
        // Paper: DRS overhead <6.5% in training, <19.5% in inference.
        for net in fig6_nets() {
            for gamma in [0.5, 0.8, 0.9] {
                let m = macs(&net, gamma, 0.5);
                assert!(
                    m.search_frac_train() < 0.075,
                    "{}: train search frac {:.3}",
                    net.name,
                    m.search_frac_train()
                );
                assert!(
                    m.search_frac_infer() < 0.21,
                    "{}: infer search frac {:.3}",
                    net.name,
                    m.search_frac_infer()
                );
            }
        }
    }

    #[test]
    fn reduction_monotone_in_gamma() {
        let net = shapes::vgg8(64);
        let r: Vec<f64> = [0.3, 0.5, 0.7, 0.9]
            .iter()
            .map(|&g| macs(&net, g, 0.5).train_reduction())
            .collect();
        assert!(r.windows(2).all(|w| w[1] > w[0]), "{r:?}");
    }

    #[test]
    fn unmaskable_layers_pay_full_cost() {
        let l = Layer::fc(100, 10, false);
        let (dsg, search) = layer_fwd_dsg(&l, 0.9, 0.5);
        assert_eq!(dsg, l.fwd_macs());
        assert_eq!(search, 0);
    }

    #[test]
    fn gmacs_units() {
        assert_eq!(gmacs(2_000_000_000), 2.0);
    }
}
