//! JLL projection-dimension model — rust mirror of `python/compile/jll.py`.
//!
//! k(eps, n_K) = ceil( ln(n_K) * (C1 / eps^2 + C2) ), clipped to [1, d].
//! C1/C2 calibrated against the paper's Table 1 (see the python module
//! docstring for the fit); both implementations are pinned to the same
//! table by tests.

pub const C1: f64 = 8.9;
pub const C2: f64 = 12.3;

/// Reduced dimension k for a layer with `d_in` inputs and `n_out` outputs.
pub fn projection_dim(eps: f64, n_out: usize, d_in: usize) -> usize {
    assert!(eps > 0.0 && eps < 1.0, "eps out of range: {eps}");
    assert!(n_out >= 1 && d_in >= 1, "bad dims n_out={n_out} d_in={d_in}");
    let k = ((n_out.max(2) as f64).ln() * (C1 / (eps * eps) + C2)).ceil() as usize;
    k.clamp(1, d_in)
}

/// Table 1 "Operations" column: low-dim VMM cost in Mi-MACs (2^20).
pub fn search_mmacs(n_pq: usize, k: usize, n_k: usize) -> f64 {
    (n_pq * k * n_k) as f64 / (1u64 << 20) as f64
}

/// Baseline full-VMM cost in Mi-MACs.
pub fn baseline_mmacs(n_pq: usize, n_crs: usize, n_k: usize) -> f64 {
    (n_pq * n_crs * n_k) as f64 / (1u64 << 20) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    // Verbatim Table 1 rows: (n_PQ, n_CRS, n_K, [(eps, dim, mmacs)]).
    const TABLE1: &[(usize, usize, usize, &[(f64, usize, f64)])] = &[
        (1024, 1152, 128, &[(0.3, 539, 67.37), (0.5, 232, 29.0), (0.7, 148, 18.5), (0.9, 119, 14.88)]),
        (256, 1152, 256, &[(0.3, 616, 38.5), (0.5, 266, 16.63), (0.7, 169, 10.56), (0.9, 136, 8.5)]),
        (256, 2304, 256, &[(0.3, 616, 38.5), (0.5, 266, 16.63), (0.7, 169, 10.56), (0.9, 136, 8.5)]),
        (64, 2304, 512, &[(0.3, 693, 21.65), (0.5, 299, 9.34), (0.7, 190, 5.94), (0.9, 154, 4.81)]),
        (64, 4608, 512, &[(0.3, 693, 21.65), (0.5, 299, 9.34), (0.7, 190, 5.94), (0.9, 154, 4.81)]),
    ];

    #[test]
    fn dims_match_table1() {
        for &(_pq, crs, nk, rows) in TABLE1 {
            for &(eps, dim, _) in rows {
                let got = projection_dim(eps, nk, crs);
                let tol = if eps < 0.85 { (0.01 * dim as f64).max(2.0) } else { 0.07 * dim as f64 };
                assert!(
                    (got as f64 - dim as f64).abs() <= tol,
                    "eps={eps} nK={nk}: got {got}, paper {dim}"
                );
            }
        }
    }

    #[test]
    fn mmacs_match_table1() {
        for &(pq, _crs, nk, rows) in TABLE1 {
            for &(_eps, dim, mmacs) in rows {
                let got = search_mmacs(pq, dim, nk);
                assert!((got - mmacs).abs() / mmacs < 0.01, "{got} vs {mmacs}");
            }
        }
    }

    #[test]
    fn baselines_match_table1() {
        let bl: &[(usize, usize, usize, f64)] = &[
            (1024, 1152, 128, 144.0),
            (256, 1152, 256, 72.0),
            (256, 2304, 256, 144.0),
            (64, 2304, 512, 72.0),
            (64, 4608, 512, 144.0),
        ];
        for &(pq, crs, nk, want) in bl {
            let got = baseline_mmacs(pq, crs, nk);
            assert!((got - want).abs() / want < 0.01);
        }
    }

    #[test]
    fn clipping() {
        assert_eq!(projection_dim(0.5, 8, 25), 25);
        assert_eq!(projection_dim(0.5, 512, 4608), 299);
    }

    #[test]
    fn matches_python_constants() {
        // Keep the two implementations lock-stepped.
        assert_eq!(C1, 8.9);
        assert_eq!(C2, 12.3);
    }
}
