//! Layer-shape zoo at the paper's published sizes (§3.1).
//!
//! Figures 6/7 and Table 1 are arithmetic over layer shapes, sparsity and
//! ZVC overhead, so the ImageNet-scale models (AlexNet, VGG16, ResNet18,
//! ResNet152, WRN-18-2) are reproduced here exactly even though training
//! them is out of CPU scope (see the substitutions note in docs/ARCHITECTURE.md).  The CIFAR and
//! FASHION models match the shapes the artifacts train.

/// One compute layer in VMM form.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Layer {
    /// sliding-window count P*Q (1 for FC)
    pub n_pq: usize,
    /// reduced-before dimension C*R*S (or fan-in for FC)
    pub n_crs: usize,
    /// output neurons K (or fan-out for FC)
    pub n_k: usize,
    /// DSG-maskable? (classifier and input-adjacent shortcut layers no)
    pub maskable: bool,
}

impl Layer {
    pub fn conv(hw: usize, c_in: usize, c_out: usize, k: usize, stride: usize) -> Layer {
        let out = hw / stride;
        Layer { n_pq: out * out, n_crs: c_in * k * k, n_k: c_out, maskable: true }
    }

    pub fn fc(d_in: usize, d_out: usize, maskable: bool) -> Layer {
        Layer { n_pq: 1, n_crs: d_in, n_k: d_out, maskable }
    }

    /// Output activation element count (per sample).
    pub fn act_elems(&self) -> usize {
        self.n_pq * self.n_k
    }

    /// Weight element count.
    pub fn weight_elems(&self) -> usize {
        self.n_crs * self.n_k
    }

    /// Dense forward MACs per sample.
    pub fn fwd_macs(&self) -> u64 {
        (self.n_pq * self.n_crs * self.n_k) as u64
    }
}

/// A whole network plus the mini-batch the paper used for it.
#[derive(Clone, Debug)]
pub struct NetShape {
    pub name: &'static str,
    pub batch: usize,
    pub input_elems: usize,
    pub layers: Vec<Layer>,
}

impl NetShape {
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems() as u64).sum()
    }
    pub fn total_acts_per_sample(&self) -> u64 {
        self.layers.iter().map(|l| l.act_elems() as u64).sum()
    }
    pub fn fwd_macs_per_sample(&self) -> u64 {
        self.layers.iter().map(|l| l.fwd_macs()).sum()
    }
    pub fn max_act_elems(&self) -> u64 {
        self.layers.iter().map(|l| l.act_elems() as u64).max().unwrap_or(0)
    }
}

/// VGG8 at the paper's width (Courbariaux-style, CIFAR 32x32).
pub fn vgg8(batch: usize) -> NetShape {
    let mut l = Vec::new();
    l.push(Layer::conv(32, 3, 128, 3, 1));
    l.push(Layer::conv(32, 128, 128, 3, 1));
    // pool -> 16
    l.push(Layer::conv(16, 128, 256, 3, 1));
    l.push(Layer::conv(16, 256, 256, 3, 1));
    // pool -> 8
    l.push(Layer::conv(8, 256, 512, 3, 1));
    l.push(Layer::conv(8, 512, 512, 3, 1));
    // pool -> 4
    l.push(Layer::fc(512 * 4 * 4, 1024, true));
    l.push(Layer::fc(1024, 10, false));
    NetShape { name: "VGG8", batch, input_elems: 3 * 32 * 32, layers: l }
}

/// The paper's customized ResNet8: 3 residual blocks + 2 FC, CIFAR.
pub fn resnet8(batch: usize) -> NetShape {
    let mut l = Vec::new();
    l.push(Layer::conv(32, 3, 16, 3, 1));
    // block 1 @16ch
    l.push(Layer::conv(32, 16, 16, 3, 1));
    l.push(Layer::conv(32, 16, 16, 3, 1));
    // block 2 @32ch stride 2
    l.push(Layer::conv(32, 16, 32, 3, 2));
    l.push(Layer::conv(16, 32, 32, 3, 1));
    l.push(Layer::conv(32, 16, 32, 1, 2)); // shortcut
    // block 3 @64ch stride 2
    l.push(Layer::conv(16, 32, 64, 3, 2));
    l.push(Layer::conv(8, 64, 64, 3, 1));
    l.push(Layer::conv(16, 32, 64, 1, 2)); // shortcut
    l.push(Layer::fc(64, 64, true));
    l.push(Layer::fc(64, 10, false));
    NetShape { name: "ResNet8", batch, input_elems: 3 * 32 * 32, layers: l }
}

/// AlexNet (ImageNet 224), original grouped topology: conv2/4/5 use
/// groups=2, halving each output's fan-in (n_CRS).
pub fn alexnet(batch: usize) -> NetShape {
    let l = vec![
        // conv1: 96 kernels 11x11 stride 4 -> 55x55
        Layer { n_pq: 55 * 55, n_crs: 3 * 11 * 11, n_k: 96, maskable: true },
        // pool -> 27; conv2 5x5 pad 2, groups 2 (48-ch fan-in)
        Layer { n_pq: 27 * 27, n_crs: 48 * 5 * 5, n_k: 256, maskable: true },
        // pool -> 13; conv3 ungrouped, conv4/5 groups 2
        Layer { n_pq: 13 * 13, n_crs: 256 * 3 * 3, n_k: 384, maskable: true },
        Layer { n_pq: 13 * 13, n_crs: 192 * 3 * 3, n_k: 384, maskable: true },
        Layer { n_pq: 13 * 13, n_crs: 192 * 3 * 3, n_k: 256, maskable: true },
        // pool -> 6; FCs
        Layer::fc(256 * 6 * 6, 4096, true),
        Layer::fc(4096, 4096, true),
        Layer::fc(4096, 1000, false),
    ];
    NetShape { name: "AlexNet", batch, input_elems: 3 * 224 * 224, layers: l }
}

/// VGG16 (ImageNet 224).
pub fn vgg16(batch: usize) -> NetShape {
    let cfg: &[(usize, usize, usize, usize)] = &[
        // (hw, c_in, c_out, repeat-first-flag unused)
        (224, 3, 64, 0),
        (224, 64, 64, 0),
        (112, 64, 128, 0),
        (112, 128, 128, 0),
        (56, 128, 256, 0),
        (56, 256, 256, 0),
        (56, 256, 256, 0),
        (28, 256, 512, 0),
        (28, 512, 512, 0),
        (28, 512, 512, 0),
        (14, 512, 512, 0),
        (14, 512, 512, 0),
        (14, 512, 512, 0),
    ];
    let mut l: Vec<Layer> =
        cfg.iter().map(|&(hw, ci, co, _)| Layer::conv(hw, ci, co, 3, 1)).collect();
    l.push(Layer::fc(512 * 7 * 7, 4096, true));
    l.push(Layer::fc(4096, 4096, true));
    l.push(Layer::fc(4096, 1000, false));
    NetShape { name: "VGG16", batch, input_elems: 3 * 224 * 224, layers: l }
}

fn resnet_stage(l: &mut Vec<Layer>, hw: usize, c_in: usize, c_out: usize, blocks: usize, stride: usize) {
    // basic blocks (2 x 3x3)
    l.push(Layer::conv(hw, c_in, c_out, 3, stride));
    let hw2 = hw / stride;
    l.push(Layer::conv(hw2, c_out, c_out, 3, 1));
    if stride != 1 || c_in != c_out {
        l.push(Layer::conv(hw, c_in, c_out, 1, stride)); // projection shortcut
    }
    for _ in 1..blocks {
        l.push(Layer::conv(hw2, c_out, c_out, 3, 1));
        l.push(Layer::conv(hw2, c_out, c_out, 3, 1));
    }
}

/// ResNet18 (ImageNet 224), basic blocks.
pub fn resnet18(batch: usize) -> NetShape {
    let mut l = Vec::new();
    l.push(Layer { n_pq: 112 * 112, n_crs: 3 * 7 * 7, n_k: 64, maskable: true });
    resnet_stage(&mut l, 56, 64, 64, 2, 1);
    resnet_stage(&mut l, 56, 64, 128, 2, 2);
    resnet_stage(&mut l, 28, 128, 256, 2, 2);
    resnet_stage(&mut l, 14, 256, 512, 2, 2);
    l.push(Layer::fc(512, 1000, false));
    NetShape { name: "ResNet18", batch, input_elems: 3 * 224 * 224, layers: l }
}

fn bottleneck_stage(l: &mut Vec<Layer>, hw: usize, c_in: usize, mid: usize, blocks: usize, stride: usize) {
    let c_out = mid * 4;
    // first block (may downsample)
    l.push(Layer::conv(hw, c_in, mid, 1, 1));
    l.push(Layer::conv(hw, mid, mid, 3, stride));
    let hw2 = hw / stride;
    l.push(Layer::conv(hw2, mid, c_out, 1, 1));
    l.push(Layer::conv(hw, c_in, c_out, 1, stride)); // shortcut
    for _ in 1..blocks {
        l.push(Layer::conv(hw2, c_out, mid, 1, 1));
        l.push(Layer::conv(hw2, mid, mid, 3, 1));
        l.push(Layer::conv(hw2, mid, c_out, 1, 1));
    }
}

/// ResNet152 (ImageNet 224), bottleneck blocks 3/8/36/3.
pub fn resnet152(batch: usize) -> NetShape {
    let mut l = Vec::new();
    l.push(Layer { n_pq: 112 * 112, n_crs: 3 * 7 * 7, n_k: 64, maskable: true });
    bottleneck_stage(&mut l, 56, 64, 64, 3, 1);
    bottleneck_stage(&mut l, 56, 256, 128, 8, 2);
    bottleneck_stage(&mut l, 28, 512, 256, 36, 2);
    bottleneck_stage(&mut l, 14, 1024, 512, 3, 2);
    l.push(Layer::fc(2048, 1000, false));
    NetShape { name: "ResNet152", batch, input_elems: 3 * 224 * 224, layers: l }
}

/// WRN-18-2: ResNet18 with doubled widths.
pub fn wrn18_2(batch: usize) -> NetShape {
    let mut l = Vec::new();
    l.push(Layer { n_pq: 112 * 112, n_crs: 3 * 7 * 7, n_k: 128, maskable: true });
    resnet_stage(&mut l, 56, 128, 128, 2, 1);
    resnet_stage(&mut l, 56, 128, 256, 2, 2);
    resnet_stage(&mut l, 28, 256, 512, 2, 2);
    resnet_stage(&mut l, 14, 512, 1024, 2, 2);
    l.push(Layer::fc(1024, 1000, false));
    NetShape { name: "WRN-18-2", batch, input_elems: 3 * 224 * 224, layers: l }
}

/// The five CNN benchmarks of Fig 6 / Fig 7 with the batch sizes used.
pub fn fig6_nets() -> Vec<NetShape> {
    vec![vgg8(128), resnet8(128), alexnet(256), vgg16(64), resnet152(32)]
}

/// All published shapes by name.
pub fn by_name(name: &str, batch: usize) -> Option<NetShape> {
    Some(match name {
        "vgg8" => vgg8(batch),
        "resnet8" => resnet8(batch),
        "alexnet" => alexnet(batch),
        "vgg16" => vgg16(batch),
        "resnet18" => resnet18(batch),
        "resnet152" => resnet152(batch),
        "wrn18_2" => wrn18_2(batch),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg8_layer_shapes_match_table1() {
        let net = vgg8(1);
        // Table 1 rows are VGG8 conv2..conv6
        let rows: Vec<(usize, usize, usize)> =
            net.layers[1..6].iter().map(|l| (l.n_pq, l.n_crs, l.n_k)).collect();
        assert_eq!(
            rows,
            vec![
                (1024, 1152, 128),
                (256, 1152, 256),
                (256, 2304, 256),
                (64, 2304, 512),
                (64, 4608, 512),
            ]
        );
    }

    #[test]
    fn vgg16_param_count_is_canonical() {
        // VGG16 has ~138M params (conv 14.7M + fc 123.6M)
        let net = vgg16(1);
        let w = net.total_weights();
        assert!((130_000_000..146_000_000).contains(&(w as usize)), "{w}");
    }

    #[test]
    fn alexnet_macs_canonical() {
        // ~0.7 GMACs forward per sample (conv-dominated)
        let net = alexnet(1);
        let m = net.fwd_macs_per_sample();
        assert!((600_000_000..800_000_000).contains(&(m as usize)), "{m}");
    }

    #[test]
    fn resnet18_macs_canonical() {
        // ~1.8 GMACs per 224x224 sample
        let net = resnet18(1);
        let m = net.fwd_macs_per_sample();
        assert!((1_500_000_000..2_100_000_000).contains(&(m as usize)), "{m}");
    }

    #[test]
    fn resnet152_macs_canonical() {
        // ~11.5 GMACs per sample
        let net = resnet152(1);
        let m = net.fwd_macs_per_sample();
        assert!((10_000_000_000..13_000_000_000).contains(&(m as u64 as usize)), "{m}");
    }

    #[test]
    fn resnet152_params_canonical() {
        // ~60M params
        let net = resnet152(1);
        let w = net.total_weights();
        assert!((55_000_000..65_000_000).contains(&(w as usize)), "{w}");
    }

    #[test]
    fn activation_dominance_at_large_batch() {
        // Fig 1(c): at large batch, activations dwarf weights for convnets.
        let net = vgg8(128);
        let acts = net.total_acts_per_sample() * 128;
        assert!(acts > net.total_weights());
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["vgg8", "resnet8", "alexnet", "vgg16", "resnet18", "resnet152", "wrn18_2"] {
            assert!(by_name(n, 8).is_some(), "{n}");
        }
        assert!(by_name("nope", 8).is_none());
    }
}
