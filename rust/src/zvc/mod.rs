//! Zero-value compression (ZVC) codec — Zhang'00 / Vijaykumar'15 /
//! Rhu'18, as used by the paper for representational-cost reduction
//! (§3.3, Fig 6).
//!
//! Encoding: a 1-bit-per-element presence bitmask + the packed non-zero
//! f32 values.  Compressed size = ceil(n/8) bytes + 4 * nnz bytes; the
//! paper's memory figures (and our Fig 6 bench) use exactly this
//! arithmetic.  Since PR 4 this module is not just the codec proof — it
//! is the storage engine behind the ZVC training tape
//! ([`crate::native::train::TapeStorage::Zvc`]), so the hot-path entry
//! points are allocation-conscious:
//!
//! * [`compress_into`] / [`decompress_into`] reuse the caller's
//!   [`Compressed`] / `Vec<f32>` buffers (capacity survives across
//!   layers and steps — no per-tensor allocation once warm).
//! * [`compress_parallel_into`] chunks the input at bitmask-byte
//!   boundaries and compresses on the
//!   [`crate::sparse::pool::WorkerPool`], producing output BIT-IDENTICAL
//!   to the serial path for any thread budget (same invariant as the
//!   sparse engines).
//! * Decompression restores the exact stored bits of every non-zero
//!   value; zeros come back as +0.0.  The codec is value-centric: -0.0
//!   compresses away like the zero it compares equal to (see the ±0.0
//!   test), which is what makes compressed-tape training bit-identical
//!   to dense-tape training — IEEE arithmetic cannot distinguish the
//!   re-canonicalized zeros downstream.
//!
//! [`to_bytes`] / [`from_bytes`] serialize for checkpointing;
//! `from_bytes` is total (never panics) and rejects non-canonical
//! buffers — truncation, length-field corruption, and padding bits set
//! beyond `n` all return `None`.

use crate::sparse::pool::{Task, WorkerPool};
use crate::sparse::simd;

/// A ZVC-compressed f32 buffer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Compressed {
    pub n: usize,
    pub bitmask: Vec<u8>,
    pub values: Vec<f32>,
}

impl Compressed {
    /// An empty buffer ready for [`compress_into`] reuse.
    pub fn new() -> Compressed {
        Compressed::default()
    }

    /// Compressed size in bytes (bitmask + packed values).
    pub fn nbytes(&self) -> usize {
        self.bitmask.len() + 4 * self.values.len()
    }

    /// Dense (uncompressed) size in bytes.
    pub fn dense_nbytes(&self) -> usize {
        4 * self.n
    }

    /// Number of stored (non-zero) values.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Compression ratio (dense / compressed); > 1 means we won.
    pub fn ratio(&self) -> f64 {
        self.dense_nbytes() as f64 / self.nbytes() as f64
    }
}

/// Compress a dense f32 slice into `out`, reusing its buffers (the
/// allocation-free twin of [`compress`]).
pub fn compress_into(xs: &[f32], out: &mut Compressed) {
    out.n = xs.len();
    out.bitmask.clear();
    out.bitmask.resize(xs.len().div_ceil(8), 0);
    out.values.clear();
    for (i, &x) in xs.iter().enumerate() {
        if x != 0.0 {
            out.bitmask[i / 8] |= 1 << (i % 8);
            out.values.push(x);
        }
    }
}

/// Compress a dense f32 slice.
pub fn compress(xs: &[f32]) -> Compressed {
    let mut out = Compressed::new();
    compress_into(xs, &mut out);
    out
}

/// Elements below which the parallel path degrades to serial (dispatch
/// overhead would dominate a single memory sweep).
const PAR_MIN_ELEMS: usize = 16 * 1024;

/// Chunk layout of a parallel compress: chunk length is a multiple of 8
/// elements, so every chunk owns whole bitmask bytes and a disjoint
/// span of the packed values.
#[derive(Clone, Copy)]
struct ChunkPlan {
    chunk: usize,
    n_chunks: usize,
}

/// `None` = run serial (small input or budget of 1).
fn chunk_plan(n: usize, threads: usize) -> Option<ChunkPlan> {
    let parts = threads.max(1).min(n / PAR_MIN_ELEMS);
    if parts <= 1 {
        return None;
    }
    let chunk = n.div_ceil(parts).div_ceil(8) * 8;
    Some(ChunkPlan { chunk, n_chunks: n.div_ceil(chunk) })
}

/// Pass 1 on the pool: per-chunk bitmask fill + nnz count.  Resets and
/// fills `out.bitmask`; returns per-chunk counts.  `bm` is the bitmask
/// primitive each chunk runs — every entry in a kernel table produces
/// byte-identical masks and counts, so swapping it never changes the
/// encoding, only the sweep speed.
fn bitmask_count_pass(
    xs: &[f32],
    plan: ChunkPlan,
    bm: simd::BitmaskCountFn,
    out: &mut Compressed,
) -> Vec<usize> {
    let n = xs.len();
    out.n = n;
    out.bitmask.clear();
    out.bitmask.resize(n.div_ceil(8), 0);
    let mut nnz = vec![0usize; plan.n_chunks];
    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(plan.n_chunks);
    let mut mask_rest: &mut [u8] = &mut out.bitmask;
    let mut nnz_rest: &mut [usize] = &mut nnz;
    for ci in 0..plan.n_chunks {
        let lo = ci * plan.chunk;
        let hi = (lo + plan.chunk).min(n);
        let (mmine, mtail) = mask_rest.split_at_mut((hi - lo).div_ceil(8));
        mask_rest = mtail;
        let (cmine, ctail) = nnz_rest.split_at_mut(1);
        nnz_rest = ctail;
        let xchunk = &xs[lo..hi];
        tasks.push(Box::new(move || {
            cmine[0] = bm(xchunk, mmine);
        }));
    }
    WorkerPool::global().run(tasks);
    nnz
}

/// Pass 2 on the pool: scatter non-zero values into the disjoint spans
/// the per-chunk counts define.
fn values_pass(xs: &[f32], plan: ChunkPlan, nnz: &[usize], out: &mut Compressed) {
    let n = xs.len();
    out.values.clear();
    out.values.resize(nnz.iter().sum(), 0.0);
    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(plan.n_chunks);
    let mut val_rest: &mut [f32] = &mut out.values;
    for ci in 0..plan.n_chunks {
        let lo = ci * plan.chunk;
        let hi = (lo + plan.chunk).min(n);
        let (vmine, vtail) = val_rest.split_at_mut(nnz[ci]);
        val_rest = vtail;
        let xchunk = &xs[lo..hi];
        tasks.push(Box::new(move || {
            let mut vi = 0usize;
            for &x in xchunk {
                if x != 0.0 {
                    vmine[vi] = x;
                    vi += 1;
                }
            }
            debug_assert_eq!(vi, vmine.len());
        }));
    }
    WorkerPool::global().run(tasks);
}

/// Chunked parallel [`compress_into`] on the global [`WorkerPool`]:
/// bit-identical to the serial path for ANY thread budget.  Two passes:
/// (1) per-chunk bitmask fill + nnz count, (2) prefix-sum offsets, then
/// per-chunk value scatter.
pub fn compress_parallel_into(xs: &[f32], threads: usize, out: &mut Compressed) {
    compress_parallel_into_bm(xs, threads, simd::bitmask_count_scalar, out)
}

/// [`compress_parallel_into`] with an explicit bitmask primitive (from a
/// kernel table).  The serial small-input branch always runs the scalar
/// sweep — dispatch overhead is the enemy there, not ALU width.
pub fn compress_parallel_into_bm(
    xs: &[f32],
    threads: usize,
    bm: simd::BitmaskCountFn,
    out: &mut Compressed,
) {
    match chunk_plan(xs.len(), threads) {
        None => compress_into(xs, out),
        Some(plan) => {
            let nnz = bitmask_count_pass(xs, plan, bm, out);
            values_pass(xs, plan, &nnz, out);
        }
    }
}

/// [`compress_parallel_into`] that only completes when the encoding
/// WINS against a raw 4·n-byte dense store.  The bitmask + count pass
/// doubles as the measurement — callers need no separate nnz pre-scan.
/// `Ok(nnz)`: `out` holds the full encoding.  `Err(nnz)`: ZVC would not
/// be smaller, the value-packing pass was skipped, and `out` is NOT a
/// valid encoding (treat as dirty scratch).
pub fn compress_parallel_into_if_smaller(
    xs: &[f32],
    threads: usize,
    out: &mut Compressed,
) -> Result<usize, usize> {
    compress_parallel_into_if_smaller_bm(xs, threads, simd::bitmask_count_scalar, out)
}

/// [`compress_parallel_into_if_smaller`] with an explicit bitmask
/// primitive (from a kernel table).
pub fn compress_parallel_into_if_smaller_bm(
    xs: &[f32],
    threads: usize,
    bm: simd::BitmaskCountFn,
    out: &mut Compressed,
) -> Result<usize, usize> {
    let n = xs.len();
    match chunk_plan(n, threads) {
        None => {
            // serial: count first so a losing tensor never packs values
            let nnz = xs.iter().filter(|&&x| x != 0.0).count();
            if zvc_bytes_nnz(n, nnz) >= 4 * n {
                return Err(nnz);
            }
            compress_into(xs, out);
            debug_assert_eq!(out.nnz(), nnz);
            Ok(nnz)
        }
        Some(plan) => {
            let nnz = bitmask_count_pass(xs, plan, bm, out);
            let total: usize = nnz.iter().sum();
            if zvc_bytes_nnz(n, total) >= 4 * n {
                return Err(total);
            }
            values_pass(xs, plan, &nnz, out);
            Ok(total)
        }
    }
}

/// Decompress into `out`, reusing its capacity (the allocation-free twin
/// of [`decompress`]).  `out` is resized to `c.n`; zeros come back as
/// +0.0, stored values keep their exact bits.
pub fn decompress_into(c: &Compressed, out: &mut Vec<f32>) {
    out.clear();
    out.resize(c.n, 0.0);
    let mut vi = 0usize;
    for (bi, &byte) in c.bitmask.iter().enumerate() {
        let mut b = byte;
        while b != 0 {
            let bit = b.trailing_zeros() as usize;
            out[bi * 8 + bit] = c.values[vi];
            vi += 1;
            b &= b - 1;
        }
    }
    debug_assert_eq!(vi, c.values.len());
}

/// Decompress back to a dense vector.
pub fn decompress(c: &Compressed) -> Vec<f32> {
    let mut out = Vec::new();
    decompress_into(c, &mut out);
    out
}

/// Analytic compressed size for `n` f32 elements at `sparsity` zero
/// fraction — the formula behind the Fig 6 memory model (matches
/// `compress(..).nbytes()` exactly for that sparsity).
pub fn zvc_bytes(n: usize, sparsity: f64) -> usize {
    let nnz = ((1.0 - sparsity) * n as f64).round() as usize;
    zvc_bytes_nnz(n, nnz)
}

/// Exact compressed size for `n` elements with `nnz` non-zeros — what
/// `compress` produces, without going through a float sparsity.  The
/// tape meter cross-check is stated in this form.
pub fn zvc_bytes_nnz(n: usize, nnz: usize) -> usize {
    n.div_ceil(8) + 4 * nnz
}

/// Serialize to bytes (checkpointing sparse activations).
pub fn to_bytes(c: &Compressed) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + c.nbytes());
    out.extend_from_slice(&(c.n as u64).to_le_bytes());
    out.extend_from_slice(&c.bitmask);
    for v in &c.values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize from bytes.  Total: any input — truncated, bit-flipped,
/// length-corrupted — returns `None` rather than panicking, and every
/// `Some(c)` is canonical (`to_bytes(&c)` reproduces the input), which
/// is what makes a restored tape record safe to decompress.
pub fn from_bytes(b: &[u8]) -> Option<Compressed> {
    if b.len() < 8 {
        return None;
    }
    let n = usize::try_from(u64::from_le_bytes(b[..8].try_into().ok()?)).ok()?;
    let mlen = n.div_ceil(8);
    let rest = b.len() - 8;
    if rest < mlen {
        return None; // truncated / length field corrupted upward
    }
    let bitmask = &b[8..8 + mlen];
    if n % 8 != 0 && bitmask[mlen - 1] >> (n % 8) != 0 {
        // padding bits beyond n set: nnz accounting would disagree with
        // what compress() produces, so decompression could misalign
        return None;
    }
    let nnz: usize = bitmask.iter().map(|x| x.count_ones() as usize).sum();
    if rest - mlen != nnz.checked_mul(4)? {
        return None;
    }
    let vstart = 8 + mlen;
    let values = (0..nnz)
        .map(|i| {
            f32::from_le_bytes(b[vstart + 4 * i..vstart + 4 * i + 4].try_into().unwrap())
        })
        .collect();
    Some(Compressed { n, bitmask: bitmask.to_vec(), values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn sparse_vec(rng: &mut Pcg32, n: usize, sparsity: f32) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.uniform() < sparsity { 0.0 } else { rng.normal() })
            .collect()
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg32::seeded(21);
        for &n in &[0usize, 1, 7, 8, 9, 1000] {
            for &s in &[0.0f32, 0.5, 0.9, 1.0] {
                let xs = sparse_vec(&mut rng, n, s);
                let c = compress(&xs);
                assert_eq!(decompress(&c), xs, "n={n} s={s}");
            }
        }
    }

    #[test]
    fn ratio_improves_with_sparsity() {
        let mut rng = Pcg32::seeded(22);
        let dense = compress(&sparse_vec(&mut rng, 4096, 0.0));
        let half = compress(&sparse_vec(&mut rng, 4096, 0.5));
        let ninety = compress(&sparse_vec(&mut rng, 4096, 0.9));
        assert!(dense.ratio() < 1.0); // bitmask overhead loses when dense
        assert!(half.ratio() > 1.5 && half.ratio() < 2.1);
        assert!(ninety.ratio() > 5.0);
    }

    #[test]
    fn analytic_matches_actual() {
        let mut rng = Pcg32::seeded(23);
        let n = 10_000;
        let xs = sparse_vec(&mut rng, n, 0.8);
        let c = compress(&xs);
        let actual_sparsity = 1.0 - c.nnz() as f64 / n as f64;
        assert_eq!(zvc_bytes(n, actual_sparsity), c.nbytes());
        assert_eq!(zvc_bytes_nnz(n, c.nnz()), c.nbytes());
    }

    #[test]
    fn negative_zero_is_nonzero_by_bits_but_equal_zero() {
        // -0.0 == 0.0 in IEEE; it compresses away (value-centric, like the
        // frequent-value cache the codec descends from).
        let c = compress(&[-0.0, 1.0]);
        assert_eq!(c.values, vec![1.0]);
        assert_eq!(decompress(&c), vec![0.0, 1.0]);
        // the decompressed zero is canonical +0.0
        assert_eq!(decompress(&c)[0].to_bits(), 0);
    }

    #[test]
    fn into_variants_reuse_buffers_and_match() {
        let mut rng = Pcg32::seeded(25);
        let mut c = Compressed::new();
        let mut dec = Vec::new();
        for &n in &[100usize, 7, 1000, 0, 64] {
            let xs = sparse_vec(&mut rng, n, 0.6);
            compress_into(&xs, &mut c);
            assert_eq!(c, compress(&xs), "n={n}");
            decompress_into(&c, &mut dec);
            assert_eq!(dec, xs, "n={n}");
        }
    }

    #[test]
    fn parallel_compress_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(26);
        // sizes straddling the serial cutoff, chunk boundaries, and
        // non-multiple-of-8 tails
        for &n in &[0usize, 5, 4096, PAR_MIN_ELEMS, 3 * PAR_MIN_ELEMS + 13] {
            for &s in &[0.0f32, 0.5, 1.0] {
                let xs = sparse_vec(&mut rng, n, s);
                let want = compress(&xs);
                for &t in &[1usize, 2, 3, 8] {
                    let mut got = Compressed::new();
                    compress_parallel_into(&xs, t, &mut got);
                    assert_eq!(got, want, "n={n} s={s} t={t}");
                }
            }
        }
    }

    #[test]
    fn if_smaller_agrees_with_unconditional_compress() {
        let mut rng = Pcg32::seeded(28);
        for &n in &[0usize, 5, 4096, PAR_MIN_ELEMS, 2 * PAR_MIN_ELEMS + 9] {
            for &s in &[0.0f32, 0.02, 0.5, 1.0] {
                let xs = sparse_vec(&mut rng, n, s);
                let want = compress(&xs);
                let wins = want.nbytes() < 4 * n;
                for &t in &[1usize, 2, 8] {
                    let mut got = Compressed::new();
                    match compress_parallel_into_if_smaller(&xs, t, &mut got) {
                        Ok(nnz) => {
                            assert!(wins, "n={n} s={s} t={t}: compressed a loser");
                            assert_eq!(nnz, want.nnz());
                            assert_eq!(got, want);
                        }
                        Err(nnz) => {
                            assert!(!wins, "n={n} s={s} t={t}: refused a winner");
                            assert_eq!(nnz, want.nnz());
                        }
                    }
                }
            }
        }
    }

    /// Bit-level roundtrip over the awkward f32 population: NaN (payload
    /// preserved), ±0.0 (negative zero canonicalizes to +0.0 — the one
    /// deliberate bit change), ±inf, subnormals.
    #[test]
    fn roundtrip_preserves_bits_of_nonzeros() {
        let specials = [
            f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN with payload
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE / 2.0, // subnormal
            -f32::MIN_POSITIVE / 4.0,
            f32::MAX,
            1.0e-40, // subnormal literal
            0.0,
            -0.0,
        ];
        let mut rng = Pcg32::seeded(27);
        for &n in &[1usize, 7, 8, 9, 333] {
            let xs: Vec<f32> = (0..n)
                .map(|_| specials[rng.below(specials.len() as u32) as usize])
                .collect();
            let back = decompress(&compress(&xs));
            for (i, (&a, &b)) in xs.iter().zip(&back).enumerate() {
                let want = if a == 0.0 { 0 } else { a.to_bits() }; // ±0 -> +0
                assert_eq!(b.to_bits(), want, "n={n} i={i} {a} vs {b}");
            }
        }
        // n = 0 degenerate
        assert_eq!(decompress(&compress(&[])), Vec::<f32>::new());
    }

    #[test]
    fn serde_roundtrip() {
        let mut rng = Pcg32::seeded(24);
        let xs = sparse_vec(&mut rng, 333, 0.7);
        let c = compress(&xs);
        let b = to_bytes(&c);
        let c2 = from_bytes(&b).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn serde_rejects_truncated() {
        let c = compress(&[1.0, 0.0, 2.0]);
        let b = to_bytes(&c);
        assert!(from_bytes(&b[..b.len() - 1]).is_none());
        assert!(from_bytes(&[1, 2, 3]).is_none());
    }

    #[test]
    fn serde_rejects_noncanonical_padding() {
        // n = 3 uses 3 bits; setting a padding bit keeps the popcount
        // consistent with a longer value section that isn't there OR
        // desyncs decompression — both must be rejected
        let c = compress(&[1.0, 0.0, 2.0]);
        let mut b = to_bytes(&c);
        b[8] |= 1 << 6; // padding bit inside the single mask byte
        assert!(from_bytes(&b).is_none());
    }

    /// Fuzz-style robustness: seeded corpus of valid buffers, then every
    /// truncation length, random bit flips, and length-field rewrites.
    /// `from_bytes` must never panic and every `Some` must re-serialize
    /// to exactly the bytes it was parsed from (canonical).
    #[test]
    fn serde_fuzz_never_panics_and_some_is_canonical() {
        let mut rng = Pcg32::seeded(0xf022);
        let mut corpus: Vec<Vec<u8>> = Vec::new();
        for &n in &[0usize, 1, 7, 8, 9, 31, 256] {
            for &s in &[0.0f32, 0.5, 1.0] {
                let xs = sparse_vec(&mut rng, n, s);
                corpus.push(to_bytes(&compress(&xs)));
            }
        }
        let mut parsed = 0usize;
        let mut check = |b: &[u8]| {
            if let Some(c) = from_bytes(b) {
                assert_eq!(to_bytes(&c), b, "non-canonical accept ({} bytes)", b.len());
                parsed += 1;
            }
        };
        for base in &corpus {
            // every truncation point
            for cut in 0..=base.len() {
                check(&base[..cut]);
            }
            // random single-bit flips (mask, values, and length field)
            for _ in 0..200 {
                let mut b = base.clone();
                if b.is_empty() {
                    continue;
                }
                let bit = rng.below((b.len() * 8) as u32) as usize;
                b[bit / 8] ^= 1 << (bit % 8);
                check(&b);
            }
            // length-field corruption: small, huge, and near-overflow n
            for n_lie in [0u64, 1, 1 << 20, u64::MAX / 2, u64::MAX] {
                let mut b = base.clone();
                if b.len() >= 8 {
                    b[..8].copy_from_slice(&n_lie.to_le_bytes());
                    check(&b);
                }
            }
        }
        // the unmutated corpus itself must parse
        assert!(parsed >= corpus.len(), "only {parsed} parses");
    }

    #[test]
    fn nbytes_accounting() {
        let c = compress(&[0.0; 16]);
        assert_eq!(c.nbytes(), 2); // 16 bits of mask, no values
        let c = compress(&[1.0; 16]);
        assert_eq!(c.nbytes(), 2 + 64);
    }
}
