//! Zero-value compression (ZVC) codec — Zhang'00 / Vijaykumar'15 /
//! Rhu'18, as used by the paper for representational-cost reduction
//! (§3.3, Fig 6).
//!
//! Encoding: a 1-bit-per-element presence bitmask + the packed non-zero
//! f32 values.  Compressed size = ceil(n/8) bytes + 4 * nnz bytes; the
//! paper's memory figures (and our Fig 6 bench) use exactly this
//! arithmetic, and this module is the executable proof that the encoding
//! round-trips.

/// A ZVC-compressed f32 buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct Compressed {
    pub n: usize,
    pub bitmask: Vec<u8>,
    pub values: Vec<f32>,
}

impl Compressed {
    /// Compressed size in bytes (bitmask + packed values).
    pub fn nbytes(&self) -> usize {
        self.bitmask.len() + 4 * self.values.len()
    }

    /// Dense (uncompressed) size in bytes.
    pub fn dense_nbytes(&self) -> usize {
        4 * self.n
    }

    /// Compression ratio (dense / compressed); > 1 means we won.
    pub fn ratio(&self) -> f64 {
        self.dense_nbytes() as f64 / self.nbytes() as f64
    }
}

/// Compress a dense f32 slice.
pub fn compress(xs: &[f32]) -> Compressed {
    let n = xs.len();
    let mut bitmask = vec![0u8; n.div_ceil(8)];
    let mut values = Vec::new();
    for (i, &x) in xs.iter().enumerate() {
        if x != 0.0 {
            bitmask[i / 8] |= 1 << (i % 8);
            values.push(x);
        }
    }
    Compressed { n, bitmask, values }
}

/// Decompress back to a dense vector.
pub fn decompress(c: &Compressed) -> Vec<f32> {
    let mut out = vec![0.0f32; c.n];
    let mut vi = 0;
    for i in 0..c.n {
        if c.bitmask[i / 8] & (1 << (i % 8)) != 0 {
            out[i] = c.values[vi];
            vi += 1;
        }
    }
    debug_assert_eq!(vi, c.values.len());
    out
}

/// Analytic compressed size for `n` f32 elements at `sparsity` zero
/// fraction — the formula behind the Fig 6 memory model (matches
/// `compress(..).nbytes()` exactly for that sparsity).
pub fn zvc_bytes(n: usize, sparsity: f64) -> usize {
    let nnz = ((1.0 - sparsity) * n as f64).round() as usize;
    n.div_ceil(8) + 4 * nnz
}

/// Serialize to bytes (checkpointing sparse activations).
pub fn to_bytes(c: &Compressed) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + c.nbytes());
    out.extend_from_slice(&(c.n as u64).to_le_bytes());
    out.extend_from_slice(&c.bitmask);
    for v in &c.values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize from bytes.
pub fn from_bytes(b: &[u8]) -> Option<Compressed> {
    if b.len() < 8 {
        return None;
    }
    let n = u64::from_le_bytes(b[..8].try_into().ok()?) as usize;
    let mlen = n.div_ceil(8);
    if b.len() < 8 + mlen {
        return None;
    }
    let bitmask = b[8..8 + mlen].to_vec();
    let nnz: usize = bitmask.iter().map(|x| x.count_ones() as usize).sum();
    let vstart = 8 + mlen;
    if b.len() != vstart + 4 * nnz {
        return None;
    }
    let values = (0..nnz)
        .map(|i| {
            f32::from_le_bytes(b[vstart + 4 * i..vstart + 4 * i + 4].try_into().unwrap())
        })
        .collect();
    Some(Compressed { n, bitmask, values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn sparse_vec(rng: &mut Pcg32, n: usize, sparsity: f32) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.uniform() < sparsity { 0.0 } else { rng.normal() })
            .collect()
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg32::seeded(21);
        for &n in &[0usize, 1, 7, 8, 9, 1000] {
            for &s in &[0.0f32, 0.5, 0.9, 1.0] {
                let xs = sparse_vec(&mut rng, n, s);
                let c = compress(&xs);
                assert_eq!(decompress(&c), xs, "n={n} s={s}");
            }
        }
    }

    #[test]
    fn ratio_improves_with_sparsity() {
        let mut rng = Pcg32::seeded(22);
        let dense = compress(&sparse_vec(&mut rng, 4096, 0.0));
        let half = compress(&sparse_vec(&mut rng, 4096, 0.5));
        let ninety = compress(&sparse_vec(&mut rng, 4096, 0.9));
        assert!(dense.ratio() < 1.0); // bitmask overhead loses when dense
        assert!(half.ratio() > 1.5 && half.ratio() < 2.1);
        assert!(ninety.ratio() > 5.0);
    }

    #[test]
    fn analytic_matches_actual() {
        let mut rng = Pcg32::seeded(23);
        let n = 10_000;
        let xs = sparse_vec(&mut rng, n, 0.8);
        let c = compress(&xs);
        let actual_sparsity = 1.0 - c.values.len() as f64 / n as f64;
        assert_eq!(zvc_bytes(n, actual_sparsity), c.nbytes());
    }

    #[test]
    fn negative_zero_is_nonzero_by_bits_but_equal_zero() {
        // -0.0 == 0.0 in IEEE; it compresses away (value-centric, like the
        // frequent-value cache the codec descends from).
        let c = compress(&[-0.0, 1.0]);
        assert_eq!(c.values, vec![1.0]);
        assert_eq!(decompress(&c), vec![0.0, 1.0]);
    }

    #[test]
    fn serde_roundtrip() {
        let mut rng = Pcg32::seeded(24);
        let xs = sparse_vec(&mut rng, 333, 0.7);
        let c = compress(&xs);
        let b = to_bytes(&c);
        let c2 = from_bytes(&b).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn serde_rejects_truncated() {
        let c = compress(&[1.0, 0.0, 2.0]);
        let b = to_bytes(&c);
        assert!(from_bytes(&b[..b.len() - 1]).is_none());
        assert!(from_bytes(&[1, 2, 3]).is_none());
    }

    #[test]
    fn nbytes_accounting() {
        let c = compress(&[0.0; 16]);
        assert_eq!(c.nbytes(), 2); // 16 bits of mask, no values
        let c = compress(&[1.0; 16]);
        assert_eq!(c.nbytes(), 2 + 64);
    }
}
