//! Artifact metadata: the buffer-layout contract emitted by
//! `python/compile/aot.py` (`<variant>.meta.json`).
//!
//! The meta file is the ONLY channel through which rust learns the flat
//! argument order of an HLO artifact; python's pytree flattening (dict
//! keys sorted) is mirrored verbatim into the `state` / `wps` / `rs`
//! lists, so the runtime can thread buffers positionally.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
    pub fn bytes(self) -> usize {
        4
    }
}

/// Init recipe for one leaf (mirrors `aot.py::_init_spec`).
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    Zeros,
    Ones,
    HeNormal { fan_in: usize },
    Ternary { s: u32 },
}

/// One flat buffer slot.
#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub init: Init,
}

impl LeafSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<LeafSpec> {
        let name = j.req_str("name")?.to_string();
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().context("shape elem"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.req_str("dtype")?)?;
        let init_j = j.req("init")?;
        let init = match init_j.req_str("kind")? {
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            "he_normal" => Init::HeNormal { fan_in: init_j.req_usize("fan_in")? },
            "ternary" => Init::Ternary { s: init_j.req_usize("s")? as u32 },
            other => bail!("unknown init kind {other:?}"),
        };
        Ok(LeafSpec { name, shape, dtype, init })
    }
}

/// Group sizes within the flat state list (concatenated in this order).
#[derive(Clone, Copy, Debug, Default)]
pub struct Counts {
    pub params: usize,
    pub vel: usize,
    pub bn: usize,
    pub vbn: usize,
    pub bn_state: usize,
    pub wps: usize,
    pub rs: usize,
    pub dsg: usize,
}

/// DSG layer description (for reporting / cost cross-checks).
#[derive(Clone, Debug)]
pub struct DsgLayer {
    pub path: String,
    pub k: usize,
    pub d_in: usize,
    pub n_out: usize,
}

/// Serialized model topology unit (drives the native inference engine).
#[derive(Clone, Debug, PartialEq)]
pub enum Unit {
    Dense { d_in: usize, d_out: usize },
    Classifier { d_in: usize, d_out: usize },
    Conv { c_in: usize, c_out: usize, ksize: usize, stride: usize, pad: usize },
    Residual { c_in: usize, c_out: usize, stride: usize },
    MaxPool { size: usize },
    GlobalAvgPool,
    Flatten,
}

impl Unit {
    fn from_json(j: &Json) -> Result<Unit> {
        Ok(match j.req_str("kind")? {
            "dense" => Unit::Dense {
                d_in: j.req_usize("d_in")?,
                d_out: j.req_usize("d_out")?,
            },
            "classifier" => Unit::Classifier {
                d_in: j.req_usize("d_in")?,
                d_out: j.req_usize("d_out")?,
            },
            "conv" => Unit::Conv {
                c_in: j.req_usize("c_in")?,
                c_out: j.req_usize("c_out")?,
                ksize: j.req_usize("ksize")?,
                stride: j.req_usize("stride")?,
                pad: j.req_usize("pad")?,
            },
            "residual" => Unit::Residual {
                c_in: j.req_usize("c_in")?,
                c_out: j.req_usize("c_out")?,
                stride: j.req_usize("stride")?,
            },
            "maxpool" => Unit::MaxPool { size: j.req_usize("size")? },
            "gap" => Unit::GlobalAvgPool,
            "flatten" => Unit::Flatten,
            other => bail!("unknown unit kind {other:?}"),
        })
    }
}

/// Parsed `<variant>.meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub name: String,
    pub base_model: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub strategy: String,
    pub eps: f64,
    pub double_mask: bool,
    pub use_bn: bool,
    pub files: std::collections::BTreeMap<String, String>,
    /// Per artifact kind: which flat input indices survived XLA DCE.
    /// The runtime must supply exactly these (e.g. `step` is dropped from
    /// non-random variants; wps/rs from dense ones).
    pub kept: std::collections::BTreeMap<String, Vec<usize>>,
    pub counts: Counts,
    /// params ++ vel ++ bn ++ vbn ++ bn_state, flat order
    pub state: Vec<LeafSpec>,
    pub wps: Vec<LeafSpec>,
    pub rs: Vec<LeafSpec>,
    /// index into the state list of each DSG layer's weight (dsg order)
    pub dsg_weight_indices: Vec<usize>,
    pub dsg_layers: Vec<DsgLayer>,
    /// model topology (empty for metas written before topology export)
    pub units: Vec<Unit>,
    pub dir: PathBuf,
}

impl Meta {
    pub fn load(dir: &Path, variant: &str) -> Result<Meta> {
        let path = dir.join(format!("{variant}.meta.json"));
        let txt = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&txt).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Meta> {
        let opts = j.req("opts")?;
        let counts_j = j.req("counts")?;
        let counts = Counts {
            params: counts_j.req_usize("params")?,
            vel: counts_j.req_usize("vel")?,
            bn: counts_j.req_usize("bn")?,
            vbn: counts_j.req_usize("vbn")?,
            bn_state: counts_j.req_usize("bn_state")?,
            wps: counts_j.req_usize("wps")?,
            rs: counts_j.req_usize("rs")?,
            dsg: counts_j.req_usize("dsg")?,
        };
        let leaves = |key: &str| -> Result<Vec<LeafSpec>> {
            j.req_arr(key)?.iter().map(LeafSpec::from_json).collect()
        };
        let state = leaves("state")?;
        let expected =
            counts.params + counts.vel + counts.bn + counts.vbn + counts.bn_state;
        if state.len() != expected {
            bail!("state has {} leaves, counts say {expected}", state.len());
        }
        let files = j
            .req("files")?
            .as_obj()
            .context("files")?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str().context("file name")?.to_string())))
            .collect::<Result<_>>()?;
        let kept = match j.get("kept") {
            Some(k) => k
                .as_obj()
                .context("kept")?
                .iter()
                .map(|(name, idxs)| {
                    let v: Vec<usize> = idxs
                        .as_arr()
                        .context("kept list")?
                        .iter()
                        .map(|i| i.as_usize().context("kept idx"))
                        .collect::<Result<_>>()?;
                    Ok((name.clone(), v))
                })
                .collect::<Result<_>>()?,
            None => Default::default(),
        };
        let dsg_layers = j
            .req_arr("dsg_layers")?
            .iter()
            .map(|l| {
                Ok(DsgLayer {
                    path: l.req_str("path")?.to_string(),
                    k: l.req_usize("k")?,
                    d_in: l.req_usize("d_in")?,
                    n_out: l.req_usize("n_out")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Meta {
            name: j.req_str("name")?.to_string(),
            base_model: j.req_str("base_model")?.to_string(),
            batch: j.req_usize("batch")?,
            input_shape: j
                .req_arr("input_shape")?
                .iter()
                .map(|v| v.as_usize().context("input_shape"))
                .collect::<Result<_>>()?,
            classes: j.req_usize("classes")?,
            strategy: opts.req_str("strategy")?.to_string(),
            eps: opts.req("eps")?.as_f64().context("eps")?,
            double_mask: opts.req("double_mask")?.as_bool().context("double_mask")?,
            use_bn: opts.req("use_bn")?.as_bool().context("use_bn")?,
            files,
            kept,
            counts,
            state,
            wps: leaves("wps")?,
            rs: leaves("rs")?,
            dsg_weight_indices: j
                .req_arr("dsg_weight_indices")?
                .iter()
                .map(|v| v.as_usize().context("dsg_weight_indices"))
                .collect::<Result<_>>()?,
            dsg_layers,
            units: match j.get("units") {
                Some(u) => u
                    .as_arr()
                    .context("units")?
                    .iter()
                    .map(Unit::from_json)
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            },
            dir: dir.to_path_buf(),
        })
    }

    /// Filter a full flat input list down to the indices the compiled
    /// artifact actually kept (identity when no kept info is recorded).
    pub fn filter_kept<T: Clone>(&self, kind: &str, inputs: Vec<T>) -> Vec<T> {
        match self.kept.get(kind) {
            None => inputs,
            Some(idxs) => {
                let mut out = Vec::with_capacity(idxs.len());
                for &i in idxs {
                    out.push(inputs[i].clone());
                }
                out
            }
        }
    }

    /// Absolute path of one artifact file ("train" / "forward" / ...).
    pub fn file(&self, kind: &str) -> Result<PathBuf> {
        let f = self
            .files
            .get(kind)
            .ok_or_else(|| anyhow::anyhow!("{}: no {kind:?} artifact", self.name))?;
        Ok(self.dir.join(f))
    }

    pub fn has_file(&self, kind: &str) -> bool {
        self.files.contains_key(kind)
    }

    /// Ranges of the state list: [params, vel, bn, vbn, bn_state].
    pub fn group_ranges(&self) -> [std::ops::Range<usize>; 5] {
        let c = &self.counts;
        let p = c.params;
        let v = p + c.vel;
        let b = v + c.bn;
        let vb = b + c.vbn;
        let bs = vb + c.bn_state;
        [0..p, p..v, v..b, b..vb, vb..bs]
    }

    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Total parameter element count (the "model size" statistic).
    pub fn param_elems(&self) -> usize {
        self.state[self.group_ranges()[0].clone()]
            .iter()
            .map(|l| l.elems())
            .sum()
    }

    /// List all variants in an artifact dir (from index.json).
    pub fn list_variants(dir: &Path) -> Result<Vec<String>> {
        let txt = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("reading {dir:?}/index.json"))?;
        let j = Json::parse(&txt).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(j.as_obj().context("index")?.keys().cloned().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta_json() -> &'static str {
        r#"{
 "name": "tiny", "base_model": "tiny", "batch": 4,
 "input_shape": [8], "classes": 2,
 "opts": {"eps": 0.5, "strategy": "drs", "double_mask": true, "use_bn": true},
 "files": {"train": "tiny.train.hlo.txt"},
 "counts": {"params": 1, "vel": 1, "bn": 0, "vbn": 0, "bn_state": 0, "wps": 1, "rs": 1, "dsg": 1},
 "state": [
   {"name": "params.0.w", "shape": [8, 2], "dtype": "f32", "init": {"kind": "he_normal", "fan_in": 8}},
   {"name": "vel.0.w", "shape": [8, 2], "dtype": "f32", "init": {"kind": "zeros"}}
 ],
 "wps": [{"name": "wp.0", "shape": [3, 2], "dtype": "f32", "init": {"kind": "zeros"}}],
 "rs": [{"name": "r.0", "shape": [3, 8], "dtype": "f32", "init": {"kind": "ternary", "s": 3}}],
 "dsg_weight_indices": [0],
 "dsg_layers": [{"path": "u0", "k": 3, "d_in": 8, "n_out": 2}]
}"#
    }

    #[test]
    fn parses_sample() {
        let j = Json::parse(sample_meta_json()).unwrap();
        let m = Meta::from_json(&j, Path::new("/tmp")).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.counts.params, 1);
        assert_eq!(m.state[0].init, Init::HeNormal { fan_in: 8 });
        assert_eq!(m.rs[0].init, Init::Ternary { s: 3 });
        assert_eq!(m.param_elems(), 16);
        assert_eq!(m.group_ranges()[0], 0..1);
        assert_eq!(m.group_ranges()[1], 1..2);
        assert!(m.has_file("train"));
        assert!(!m.has_file("project"));
        assert_eq!(m.dsg_layers[0].k, 3);
    }

    #[test]
    fn count_mismatch_rejected() {
        let bad = sample_meta_json().replace(r#""params": 1"#, r#""params": 2"#);
        let j = Json::parse(&bad).unwrap();
        assert!(Meta::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_real_mlp_meta_if_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("mlp.meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Meta::load(&dir, "mlp").unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.counts.dsg, 2);
        assert_eq!(m.dsg_weight_indices.len(), 2);
        assert_eq!(m.state.len(), 20);
        // state order: params.. vel.. bn.. vbn.. bn_state..
        assert!(m.state[0].name.starts_with("params."));
        assert!(m.state[19].name.starts_with("bn_state."));
    }
}
