//! Artifact runtime: host tensors, variant metadata, golden vectors, and
//! (behind the `xla` feature) the PJRT executor that loads HLO *text*
//! produced by `aot.py`, compiles it on the CPU PJRT client, and executes
//! it with flat host buffers.
//!
//! Interchange is HLO text (not serialized protos) — jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! All artifacts are lowered with `return_tuple=True`, so execution
//! returns a single tuple literal that we decompose into flat outputs.
//!
//! Feature gating: everything except the PJRT client itself is pure rust
//! and always available (`HostTensor`, `Meta`, `Golden`).  The `xla`
//! crate cannot be resolved offline, so `Executable` and `Runtime` have
//! a stub twin compiled when the `xla` feature is off — same API, every
//! entry point returns a clean error.  That keeps the coordinator, the
//! benches, and the examples compiling (and the native serving path
//! fully working) on a build with no PJRT toolchain.

pub mod golden;
pub mod meta;

pub use golden::Golden;
pub use meta::{Counts, DType, DsgLayer, Init, LeafSpec, Meta, Unit};
pub use pjrt::{Executable, Runtime};

use anyhow::{bail, Result};

/// A flat host tensor (f32 or i32), the runtime's exchange currency.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    S32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn s32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::S32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_s32(v: i32) -> Self {
        HostTensor::S32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::S32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::S32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutable f32 view (the native trainer updates state in place).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::S32 { data, .. } => Ok(data),
            _ => bail!("tensor is not s32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            HostTensor::S32 { data, .. } if data.len() == 1 => Ok(data[0] as f32),
            _ => bail!("not a scalar: shape {:?}", self.shape()),
        }
    }
}

/// The PJRT-backed executor (compiled only with `--features xla`).
#[cfg(feature = "xla")]
mod pjrt {
    use super::{HostTensor, Meta};
    use anyhow::{bail, Context, Result};
    use std::path::Path;

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], Vec<u8>) = match t {
            HostTensor::F32 { shape, data } => (
                xla::ElementType::F32,
                shape,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            HostTensor::S32 { shape, data } => (
                xla::ElementType::S32,
                shape,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
                Ok(HostTensor::F32 { shape: dims, data })
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
                Ok(HostTensor::S32 { shape: dims, data })
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub path: std::path::PathBuf,
    }

    impl Executable {
        /// Execute with flat inputs; returns flat outputs (tuple decomposed).
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(to_literal)
                .collect::<Result<_>>()
                .context("building input literals")?;
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow::anyhow!("execute {:?}: {e}", self.path))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
            let parts = out
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("decompose tuple: {e}"))?;
            parts.iter().map(from_literal).collect()
        }
    }

    /// The PJRT CPU runtime: client + compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: std::cell::RefCell<std::collections::BTreeMap<String, std::rc::Rc<Executable>>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
            Ok(Runtime { client, cache: Default::default() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file (cached by path).
        pub fn load(&self, path: &Path) -> Result<std::rc::Rc<Executable>> {
            let key = path.to_string_lossy().to_string();
            if let Some(e) = self.cache.borrow().get(&key) {
                return Ok(e.clone());
            }
            if !path.exists() {
                bail!("artifact {path:?} not found — run `make artifacts` first");
            }
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {path:?}: {e}"))?;
            let exe = std::rc::Rc::new(Executable { exe, path: path.to_path_buf() });
            self.cache.borrow_mut().insert(key, exe.clone());
            Ok(exe)
        }

        /// Load a variant's artifact by kind ("train" / "forward" / ...).
        pub fn load_artifact(&self, meta: &Meta, kind: &str) -> Result<std::rc::Rc<Executable>> {
            self.load(&meta.file(kind)?)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn host_tensor_roundtrip_literal() {
            let t = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
            let lit = to_literal(&t).unwrap();
            let t2 = from_literal(&lit).unwrap();
            assert_eq!(t, t2);
            let s = HostTensor::s32(&[4], vec![1, -2, 3, -4]);
            let lit = to_literal(&s).unwrap();
            assert_eq!(from_literal(&lit).unwrap(), s);
        }

        #[test]
        fn missing_artifact_is_clean_error() {
            let rt = Runtime::cpu().unwrap();
            match rt.load(Path::new("/nonexistent/foo.hlo.txt")) {
                Ok(_) => panic!("expected error"),
                Err(err) => assert!(format!("{err}").contains("make artifacts")),
            }
        }
    }
}

/// Stub executor for builds without the `xla` feature: the types exist
/// (so the coordinator and every binary compile) but construction fails
/// with an actionable error.
#[cfg(not(feature = "xla"))]
mod pjrt {
    use super::{HostTensor, Meta};
    use anyhow::{bail, Result};
    use std::path::Path;

    const NO_XLA: &str = "dsg was built without the `xla` feature — the PJRT/HLO \
                          runtime is unavailable (the native engine, `dsg serve`, and \
                          the cost models work without it); rebuild with a vendored \
                          xla-rs and `--features xla` to execute HLO artifacts";

    /// Placeholder for a compiled artifact; never constructed in this
    /// build (`Runtime::cpu` always errors first).
    pub struct Executable {
        pub path: std::path::PathBuf,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            bail!("cannot execute {:?}: {NO_XLA}", self.path)
        }
    }

    /// Stub runtime: `cpu()` fails cleanly so callers can degrade.
    pub struct Runtime {}

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            bail!(NO_XLA)
        }

        pub fn platform(&self) -> String {
            "unavailable (built without `xla`)".to_string()
        }

        pub fn load(&self, path: &Path) -> Result<std::rc::Rc<Executable>> {
            bail!("cannot load {path:?}: {NO_XLA}")
        }

        pub fn load_artifact(&self, meta: &Meta, kind: &str) -> Result<std::rc::Rc<Executable>> {
            bail!("cannot load {kind} artifact for {}: {NO_XLA}", meta.name)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(HostTensor::scalar_f32(0.5).scalar().unwrap(), 0.5);
        assert_eq!(HostTensor::scalar_s32(7).scalar().unwrap(), 7.0);
        assert!(HostTensor::f32(&[2], vec![1., 2.]).scalar().is_err());
    }

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::f32(&[1], vec![1.0]);
        assert!(t.as_s32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        HostTensor::f32(&[3], vec![1.0]);
    }

    #[test]
    #[cfg(not(feature = "xla"))]
    fn stub_runtime_errors_cleanly() {
        match Runtime::cpu() {
            Ok(_) => panic!("stub Runtime::cpu must fail"),
            Err(e) => assert!(format!("{e}").contains("xla")),
        }
    }
}
