//! Golden-vector reader: `aot.py` emits `golden/*.{json,bin}` pairs with
//! concrete input/output tensors from a real python execution; the rust
//! integration tests replay them through the loaded HLO and compare.

use super::HostTensor;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A named set of golden tensors.
#[derive(Debug)]
pub struct Golden {
    pub tensors: Vec<(String, HostTensor)>,
}

impl Golden {
    /// Load `<base>.json` + `<base>.bin`.
    pub fn load(base: &Path) -> Result<Golden> {
        let idx_path = base.with_extension("json");
        let bin_path = base.with_extension("bin");
        let idx = std::fs::read_to_string(&idx_path)
            .with_context(|| format!("reading {idx_path:?}"))?;
        let bin = std::fs::read(&bin_path).with_context(|| format!("reading {bin_path:?}"))?;
        let j = Json::parse(&idx).map_err(|e| anyhow::anyhow!("{e}"))?;
        let entries = j.as_arr().context("golden index must be an array")?;
        let mut tensors = Vec::with_capacity(entries.len());
        for e in entries {
            let name = e.req_str("name")?.to_string();
            let shape: Vec<usize> = e
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().context("shape"))
                .collect::<Result<_>>()?;
            let offset = e.req_usize("offset")?;
            let nbytes = e.req_usize("nbytes")?;
            if offset + nbytes > bin.len() {
                bail!("golden {name}: range {offset}+{nbytes} > {}", bin.len());
            }
            let raw = &bin[offset..offset + nbytes];
            let t = match e.req_str("dtype")? {
                "f32" => HostTensor::F32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                "s32" => HostTensor::S32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                other => bail!("golden dtype {other:?}"),
            };
            tensors.push((name, t));
        }
        Ok(Golden { tensors })
    }

    /// All tensors whose name starts with `prefix`, in file order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<&HostTensor> {
        self.tensors
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, t)| t)
            .collect()
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// Max |a-b| over two f32 tensors (inf on shape/type mismatch).
pub fn max_abs_diff(a: &HostTensor, b: &HostTensor) -> f32 {
    match (a.as_f32(), b.as_f32()) {
        (Ok(x), Ok(y)) if x.len() == y.len() => x
            .iter()
            .zip(y)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f32::max),
        _ => match (a.as_s32(), b.as_s32()) {
            (Ok(x), Ok(y)) if x.len() == y.len() => x
                .iter()
                .zip(y)
                .map(|(u, v)| (u - v).abs() as f32)
                .fold(0.0, f32::max),
            _ => f32::INFINITY,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_mlp_golden_if_present() {
        let base = crate::artifacts_dir().join("golden").join("mlp_step");
        if !base.with_extension("json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let g = Golden::load(&base).unwrap();
        let ins = g.with_prefix("in");
        let outs = g.with_prefix("out");
        assert_eq!(ins.len(), 29); // 20 state + 2 wp + 2 r + x,y,gamma,lr,step
        assert_eq!(outs.len(), 24); // 20 state + loss + acc + 2 densities
        // x is (64, 784) f32, y is (64,) s32
        assert_eq!(ins[24].shape(), &[64, 784]);
        assert_eq!(ins[25].shape(), &[64]);
        assert!(ins[25].as_s32().is_ok());
    }

    #[test]
    fn max_abs_diff_basics() {
        let a = HostTensor::f32(&[2], vec![1.0, 2.0]);
        let b = HostTensor::f32(&[2], vec![1.5, 2.0]);
        assert_eq!(max_abs_diff(&a, &b), 0.5);
        let c = HostTensor::s32(&[2], vec![1, 2]);
        assert_eq!(max_abs_diff(&a, &c), f32::INFINITY);
    }
}
