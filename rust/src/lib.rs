//! # DSG — Dynamic Sparse Graph for Efficient Deep Learning
//!
//! Rust + JAX + Pallas reproduction of Liu et al., ICLR 2019.
//!
//! Three layers (see `docs/ARCHITECTURE.md` for the full module map and
//! the cross-cutting contracts):
//! * **L3 (this crate)** — training coordinator, data pipeline, projected-
//!   weight refresh scheduling, metrics, sparse CPU execution engine,
//!   ZVC codec, memory/compute cost models, CLI.
//! * **L2 (python/compile)** — DSG model zoo + Algorithm-1 train step in
//!   JAX, AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels)** — Pallas kernels (projection,
//!   threshold masking, masked matmul) inside the same HLO.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! HLO artifacts through PJRT (`xla` crate) and the `coordinator` drives
//! training/inference purely from rust.

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod datasets;
pub mod drs;
pub mod memmodel;
pub mod metrics;
pub mod native;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;
pub mod zvc;

pub use tensor::Tensor;
pub use util::{Json, Pcg32};

/// Default artifacts directory (overridable with `DSG_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("DSG_ARTIFACTS") {
        return d.into();
    }
    // look upward from cwd so examples/tests work from any subdir
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("index.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
