//! Shared support for the `rust/benches/*` harness-less benchmarks that
//! regenerate the paper's tables and figures.
//!
//! Step counts are scaled by `DSG_BENCH_STEPS` (default 120) so CI can
//! shrink and a thorough run can grow the training-based benches.

use crate::config::{GammaSchedule, RunConfig};
use crate::coordinator::Trainer;
use crate::datasets::{self, Dataset};
use crate::runtime::{Meta, Runtime};
use anyhow::Result;

/// Training steps for training-based benches (env-scalable).
pub fn bench_steps() -> usize {
    std::env::var("DSG_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120)
}

pub fn header(id: &str, what: &str, paper: &str) {
    println!("==================================================================");
    println!("{id}: {what}");
    println!("paper reference: {paper}");
    println!("==================================================================");
}

/// Cached dataset pair for a config.
pub fn data_for(cfg: &RunConfig) -> (Dataset, Dataset) {
    let full = if cfg.dataset == "fashion" {
        datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed)
    } else {
        datasets::cifar_like(cfg.train_size + cfg.test_size, cfg.seed)
    };
    full.split(cfg.test_size as f64 / (cfg.train_size + cfg.test_size) as f64)
}

/// Train `variant` at constant `gamma` for the bench step budget and
/// return (final eval accuracy, trainer).
pub fn train_at(
    rt: &Runtime,
    variant: &str,
    gamma: f32,
    steps: usize,
    seed: u64,
) -> Result<(f32, Trainer)> {
    let dir = crate::artifacts_dir();
    let meta = Meta::load(&dir, variant)?;
    let mut cfg = RunConfig::preset_for_model(variant);
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.gamma = GammaSchedule::Constant(gamma);
    let (train, test) = data_for(&cfg);
    let mut t = Trainer::new(rt, meta, seed)?;
    let acc = t.train(&cfg, &train, &test)?;
    Ok((acc, t))
}

/// Render a compact accuracy-vs-gamma series.
pub fn print_series(label: &str, series: &[(f32, f32)]) {
    print!("{label:<16}");
    for (g, a) in series {
        print!("  {g:.2}:{a:.3}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_steps_default() {
        std::env::remove_var("DSG_BENCH_STEPS");
        assert_eq!(super::bench_steps(), 120);
    }
}
