//! Self-contained synthetic DSG model for serving load tests: a stack of
//! DSG dense layers (ternary projection -> low-dim virtual VMM -> shared
//! top-k threshold -> masked VMM with real column skipping) plus a dense
//! classifier, on random weights.  No artifacts, no PJRT — this is how
//! `dsg serve`, the throughput bench, and CI exercise the serving hot
//! path on a build with nothing but the rust toolchain.
//!
//! All matmuls route through `sparse::parallel` with an explicit
//! intra-op thread budget, so a server can split cores across workers
//! while keeping predictions bit-identical (the engines are row-split
//! and therefore thread-count invariant).

use crate::drs::projection::{ternary_r, TernaryIndex};
use crate::drs::topk;
use crate::sparse::parallel;
use crate::tensor::{ops, Tensor};
use crate::util::Pcg32;
use anyhow::Result;

struct SynthLayer {
    /// (n, d) transposed weights for the skipping VMM.
    wt: Tensor,
    /// (k, n) projected weights for the virtual VMM.
    wp: Tensor,
    /// Index-form ternary projection.
    ridx: TernaryIndex,
}

/// A synthetic DSG MLP with a fixed batch shape.
pub struct SynthModel {
    layers: Vec<SynthLayer>,
    /// (d_last, classes) classifier weights.
    classifier: Tensor,
    pub input_elems: usize,
    pub classes: usize,
    pub gamma: f32,
    intra_threads: usize,
}

impl SynthModel {
    /// Build from layer widths, e.g. `&[256, 512, 512]` = input 256 and
    /// two DSG hidden layers of 512.  `k` per layer follows the paper's
    /// 8x dimension reduction (min 16).
    pub fn new(seed: u64, dims: &[usize], classes: usize, gamma: f32) -> SynthModel {
        assert!(dims.len() >= 2, "need at least input + one hidden layer");
        assert!((0.0..1.0).contains(&gamma));
        let mut rng = Pcg32::seeded(seed);
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for w in dims.windows(2) {
            let (d, n) = (w[0], w[1]);
            let scale = (2.0 / d as f32).sqrt();
            let wmat = Tensor::new(&[d, n], rng.normal_vec(d * n, scale));
            let k = (d / 8).clamp(16.min(d), d);
            let r = ternary_r(&mut rng, k, d, 3);
            let wp = crate::drs::project_weights(&r, &wmat);
            layers.push(SynthLayer {
                wt: ops::transpose(&wmat),
                wp,
                ridx: TernaryIndex::from_dense(&r),
            });
        }
        let d_last = *dims.last().unwrap();
        let cscale = (1.0 / d_last as f32).sqrt();
        let classifier = Tensor::new(&[d_last, classes], rng.normal_vec(d_last * classes, cscale));
        SynthModel {
            layers,
            classifier,
            input_elems: dims[0],
            classes,
            gamma,
            intra_threads: 1,
        }
    }

    /// Set the intra-op thread budget (predictions are invariant to it).
    pub fn with_intra_threads(mut self, threads: usize) -> SynthModel {
        self.intra_threads = threads.max(1);
        self
    }

    /// Deterministic request image for load generation.
    pub fn synth_image(&self, seed: u64) -> Vec<f32> {
        Pcg32::seeded(seed).normal_vec(self.input_elems, 1.0)
    }

    /// Forward a flat (batch * input_elems) buffer to flat logits
    /// (batch * classes).  Deterministic for fixed inputs.
    pub fn forward(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            xs.len() == batch * self.input_elems,
            "batch buffer has {} elems, expected {}",
            xs.len(),
            batch * self.input_elems
        );
        let t = self.intra_threads;
        let mut h = Tensor::new(&[batch, self.input_elems], xs.to_vec());
        for layer in &self.layers {
            let xp = parallel::project_rows_parallel_with(&h, &layer.ridx, t);
            let virt = parallel::matmul_parallel_with(&xp, &layer.wp, t);
            let thr = topk::shared_threshold(&virt, self.gamma);
            let mask =
                Tensor::from_fn(virt.shape(), |i| if virt.data()[i] >= thr { 1.0 } else { 0.0 });
            let mut y = parallel::dsg_vmm_parallel_with(&h, &layer.wt, &mask, t);
            ops::relu_inplace(&mut y);
            h = y;
        }
        let logits = parallel::matmul_parallel_with(&h, &self.classifier, t);
        Ok(logits.into_data())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_determinism() {
        let m = SynthModel::new(7, &[64, 96], 10, 0.8);
        let xs: Vec<f32> = (0..4 * 64).map(|i| (i % 13) as f32 * 0.1).collect();
        let a = m.forward(&xs, 4).unwrap();
        let b = m.forward(&xs, 4).unwrap();
        assert_eq!(a.len(), 40);
        assert_eq!(a, b, "forward must be deterministic");
        assert!(m.forward(&xs, 3).is_err(), "wrong batch must error");
    }

    #[test]
    fn intra_thread_budget_does_not_change_bits() {
        let xs: Vec<f32> = Pcg32::seeded(9).normal_vec(8 * 64, 1.0);
        let base = SynthModel::new(3, &[64, 96, 80], 10, 0.7).forward(&xs, 8).unwrap();
        for t in [2usize, 4, 7] {
            let m = SynthModel::new(3, &[64, 96, 80], 10, 0.7).with_intra_threads(t);
            assert_eq!(base, m.forward(&xs, 8).unwrap(), "threads {t}");
        }
    }

    #[test]
    fn gamma_zero_is_dense() {
        // gamma 0 keeps every neuron: output must match a dense forward
        let m = SynthModel::new(5, &[32, 48], 6, 0.0);
        let xs: Vec<f32> = Pcg32::seeded(11).normal_vec(2 * 32, 1.0);
        let got = m.forward(&xs, 2).unwrap();
        assert_eq!(got.len(), 12);
        assert!(got.iter().all(|v| v.is_finite()));
    }
}
