//! Self-contained synthetic DSG model for serving load tests: a stack of
//! DSG dense layers (ternary projection -> low-dim virtual VMM -> shared
//! top-k threshold -> masked VMM with real column skipping) plus a dense
//! classifier, on random weights.  No artifacts, no PJRT — this is how
//! `dsg serve`, the throughput bench, and CI exercise the serving hot
//! path on a build with nothing but the rust toolchain.
//!
//! All matmuls route through the pool-backed `sparse::parallel` engines
//! with an explicit intra-op thread budget, so a server can split cores
//! across workers while keeping predictions bit-identical (the engines
//! are row-split and therefore thread-count invariant).  Selection uses
//! the compact [`crate::sparse::RowMask`], and every forward runs inside
//! a pooled [`ForwardWorkspace`]: with N serve workers at most N
//! workspaces exist, each reused across requests, so no projection /
//! activation / mask buffer is heap-allocated per layer in steady
//! state.

use crate::drs::projection::{ternary_r, TernaryIndex};
use crate::drs::topk;
use crate::metrics::OpsMeter;
use crate::native::{ForwardWorkspace, WorkspacePool};
use crate::sparse::parallel;
use crate::tensor::{ops, Tensor};
use crate::util::Pcg32;
use anyhow::Result;
use std::sync::Arc;

struct SynthLayer {
    /// (n, d) transposed weights for the skipping VMM.
    wt: Tensor,
    /// (k, n) projected weights for the virtual VMM.
    wp: Tensor,
    /// Index-form ternary projection.
    ridx: TernaryIndex,
}

/// A synthetic DSG MLP with a fixed batch shape.
pub struct SynthModel {
    layers: Vec<SynthLayer>,
    /// (d_last, classes) classifier weights.
    classifier: Tensor,
    pub input_elems: usize,
    pub classes: usize,
    pub gamma: f32,
    intra_threads: usize,
    selection: topk::SelectionMode,
    kernels: parallel::SparseKernels,
    ws_pool: WorkspacePool,
    /// Realized vs dense-equivalent multiply-adds across every forward
    /// (shared with the serve report via [`SynthModel::ops_meter`]).
    ops: Arc<OpsMeter>,
}

impl SynthModel {
    /// Build from layer widths, e.g. `&[256, 512, 512]` = input 256 and
    /// two DSG hidden layers of 512.  `k` per layer follows the paper's
    /// 8x dimension reduction (min 16).
    pub fn new(seed: u64, dims: &[usize], classes: usize, gamma: f32) -> SynthModel {
        assert!(dims.len() >= 2, "need at least input + one hidden layer");
        assert!((0.0..1.0).contains(&gamma));
        let mut rng = Pcg32::seeded(seed);
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for w in dims.windows(2) {
            let (d, n) = (w[0], w[1]);
            let scale = (2.0 / d as f32).sqrt();
            let wmat = Tensor::new(&[d, n], rng.normal_vec(d * n, scale));
            let k = (d / 8).clamp(16.min(d), d);
            let r = ternary_r(&mut rng, k, d, 3);
            let wp = crate::drs::project_weights(&r, &wmat);
            layers.push(SynthLayer {
                wt: ops::transpose(&wmat),
                wp,
                ridx: TernaryIndex::from_dense(&r),
            });
        }
        let d_last = *dims.last().unwrap();
        let cscale = (1.0 / d_last as f32).sqrt();
        let classifier = Tensor::new(&[d_last, classes], rng.normal_vec(d_last * classes, cscale));
        SynthModel {
            layers,
            classifier,
            input_elems: dims[0],
            classes,
            gamma,
            intra_threads: 1,
            selection: topk::SelectionMode::default(),
            kernels: parallel::SparseKernels::default(),
            ws_pool: WorkspacePool::new(),
            ops: Arc::new(OpsMeter::new()),
        }
    }

    /// Set the intra-op thread budget (predictions are invariant to it).
    pub fn with_intra_threads(mut self, threads: usize) -> SynthModel {
        self.intra_threads = threads.max(1);
        self
    }

    /// Selection mode: unstructured shared-threshold CSR masks (default)
    /// vs structured per-row top-k in the packed `FixedK` layout, which
    /// routes the masked VMM through the packed-gather kernels.
    pub fn with_selection(mut self, selection: topk::SelectionMode) -> SynthModel {
        self.selection = selection;
        self
    }

    /// Kernel mode: the masked VMM runs on the mode's kernel table —
    /// [`parallel::SparseKernels::Simd`] swaps in the runtime-detected
    /// SIMD primitives (forward dots ULP-relaxed vs scalar); any other
    /// mode serves on the bit-exact scalar table.
    pub fn with_kernels(mut self, kernels: parallel::SparseKernels) -> SynthModel {
        self.kernels = kernels;
        self
    }

    /// Shared handle to the realized-ops meter (clone it out before
    /// moving the model into a serve closure; totals accumulate across
    /// all workers and requests).
    pub fn ops_meter(&self) -> Arc<OpsMeter> {
        self.ops.clone()
    }

    /// Deterministic request image for load generation.
    pub fn synth_image(&self, seed: u64) -> Vec<f32> {
        Pcg32::seeded(seed).normal_vec(self.input_elems, 1.0)
    }

    /// Forward a flat (batch * input_elems) buffer to flat logits
    /// (batch * classes) on a pooled workspace.  Deterministic for fixed
    /// inputs, for any thread budget.
    pub fn forward(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut ws = self.ws_pool.take();
        let r = self.forward_with_workspace(xs, batch, &mut ws);
        self.ws_pool.put(ws);
        r
    }

    /// [`SynthModel::forward`] on a caller-owned workspace (the
    /// allocation-free steady state when the caller reuses it).
    pub fn forward_with_workspace(
        &self,
        xs: &[f32],
        batch: usize,
        ws: &mut ForwardWorkspace,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            xs.len() == batch * self.input_elems,
            "batch buffer has {} elems, expected {}",
            xs.len(),
            batch * self.input_elems
        );
        let t = self.intra_threads;
        ws.h.clear();
        ws.h.extend_from_slice(xs);
        let mut d = self.input_elems;
        // compound-dispatch hint: request images are dense; after a
        // masked+relu'd layer (no BN here) about half the selected
        // neurons survive
        let mut hint = 1.0f32;
        for layer in &self.layers {
            let k = layer.ridx.k;
            let n = layer.wt.shape()[0];
            // kernels fully write their outputs: resize sets length only
            ws.scratch.xp.resize(batch * k, 0.0);
            parallel::project_rows_parallel_into(&ws.h, batch, &layer.ridx, t, &mut ws.scratch.xp);
            ws.scratch.virt.resize(batch * n, 0.0);
            parallel::matmul_parallel_into(
                &ws.scratch.xp,
                batch,
                k,
                layer.wp.data(),
                n,
                t,
                &mut ws.scratch.virt,
            );
            match self.selection {
                topk::SelectionMode::Unstructured => {
                    let thr = topk::shared_threshold_slice(
                        &ws.scratch.virt,
                        n,
                        self.gamma,
                        &mut ws.scratch.thr,
                    );
                    ws.scratch.mask.fill_from_threshold(&ws.scratch.virt, batch, n, thr);
                }
                topk::SelectionMode::Structured { blocked } => {
                    let k = topk::structured_k(n, self.gamma, blocked);
                    ws.scratch
                        .mask
                        .fill_topk(&ws.scratch.virt, batch, n, k, &mut ws.scratch.pairs);
                }
            }
            ws.y.resize(batch * n, 0.0);
            let realized = parallel::dsg_vmm_compound_parallel_into_kt(
                self.kernels.table(),
                &ws.h,
                batch,
                d,
                layer.wt.data(),
                n,
                &ws.scratch.mask,
                hint,
                t,
                &mut ws.y,
            );
            self.ops.add(realized, (batch * d * n) as u64);
            // shared hint rule (no BN, no double mask in the synth MLP)
            hint = parallel::density_hint_after_layer(
                ws.scratch.mask.density() as f32,
                false,
                false,
            );
            ops::relu_slice(&mut ws.y);
            std::mem::swap(&mut ws.h, &mut ws.y);
            d = n;
        }
        let c = self.classes;
        ws.y.resize(batch * c, 0.0);
        parallel::matmul_parallel_into(&ws.h, batch, d, self.classifier.data(), c, t, &mut ws.y);
        // unmasked classifier: realized IS the dense baseline
        self.ops.add((batch * d * c) as u64, (batch * d * c) as u64);
        Ok(ws.y[..].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_determinism() {
        let m = SynthModel::new(7, &[64, 96], 10, 0.8);
        let xs: Vec<f32> = (0..4 * 64).map(|i| (i % 13) as f32 * 0.1).collect();
        let a = m.forward(&xs, 4).unwrap();
        let b = m.forward(&xs, 4).unwrap();
        assert_eq!(a.len(), 40);
        assert_eq!(a, b, "forward must be deterministic");
        assert!(m.forward(&xs, 3).is_err(), "wrong batch must error");
    }

    #[test]
    fn intra_thread_budget_does_not_change_bits() {
        let xs: Vec<f32> = Pcg32::seeded(9).normal_vec(8 * 64, 1.0);
        let base = SynthModel::new(3, &[64, 96, 80], 10, 0.7).forward(&xs, 8).unwrap();
        for t in [2usize, 4, 7] {
            let m = SynthModel::new(3, &[64, 96, 80], 10, 0.7).with_intra_threads(t);
            assert_eq!(base, m.forward(&xs, 8).unwrap(), "threads {t}");
        }
    }

    #[test]
    fn gamma_zero_is_dense() {
        // gamma 0 keeps every neuron: output must match a dense forward
        let m = SynthModel::new(5, &[32, 48], 6, 0.0);
        let xs: Vec<f32> = Pcg32::seeded(11).normal_vec(2 * 32, 1.0);
        let got = m.forward(&xs, 2).unwrap();
        assert_eq!(got.len(), 12);
        assert!(got.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn structured_selection_thread_invariant_and_dense_at_gamma_zero() {
        use crate::drs::topk::SelectionMode;
        let xs: Vec<f32> = Pcg32::seeded(21).normal_vec(6 * 64, 1.0);
        let mk = |sel: SelectionMode, t: usize| {
            SynthModel::new(17, &[64, 96, 80], 10, 0.7)
                .with_selection(sel)
                .with_intra_threads(t)
        };
        for blocked in [false, true] {
            let sel = SelectionMode::Structured { blocked };
            let base = mk(sel, 1).forward(&xs, 6).unwrap();
            assert!(base.iter().all(|v| v.is_finite()));
            for t in [2usize, 3, 8] {
                assert_eq!(base, mk(sel, t).forward(&xs, 6).unwrap(), "blocked {blocked} threads {t}");
            }
        }
        // gamma 0 keeps everything in both modes: same bits
        let xs0: Vec<f32> = Pcg32::seeded(22).normal_vec(2 * 32, 1.0);
        let a = SynthModel::new(5, &[32, 48], 6, 0.0).forward(&xs0, 2).unwrap();
        let b = SynthModel::new(5, &[32, 48], 6, 0.0)
            .with_selection(SelectionMode::Structured { blocked: false })
            .forward(&xs0, 2)
            .unwrap();
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn explicit_workspace_reuse_is_bit_exact() {
        let m = SynthModel::new(13, &[48, 64, 56], 8, 0.6).with_intra_threads(2);
        let mut ws = ForwardWorkspace::new();
        let mut fresh = Vec::new();
        let mut reused = Vec::new();
        for i in 0..4u64 {
            let xs: Vec<f32> = Pcg32::seeded(100 + i).normal_vec(4 * 48, 1.0);
            fresh.push(m.forward(&xs, 4).unwrap());
            reused.push(m.forward_with_workspace(&xs, 4, &mut ws).unwrap());
        }
        assert_eq!(fresh, reused, "reused workspace diverged from pooled path");
    }
}
