//! Socket front-end for the sharded serving engine: accepts
//! [`super::wire`] frames over TCP or a Unix-domain socket, feeds them
//! into a [`ShardedServer`], and streams responses back per connection.
//!
//! Topology: one acceptor loop ([`WireServer::run`]), and per
//! connection one reader thread (this thread) plus one writer thread
//! owning the outbound half.  The reader submits each `Request` with a
//! reply hook that encodes the [`Outcome`] and hands it to the writer's
//! channel — so responses stream back as their batches complete,
//! out-of-order by design (clients correlate by request id).  Admission
//! rejects and malformed-request errors are answered immediately from
//! the reader.
//!
//! Degradation under faults ([`ServerTuning`], counted by
//! [`crate::metrics::RecoveryCounters`]):
//!
//! * transient `accept` errors (fd exhaustion, EINTR, injected faults)
//!   back the acceptor off with a doubling sleep — the listener never
//!   dies; only the stop flag ends the loop;
//! * each connection has a read/idle deadline — a peer that goes
//!   silent is disconnected, not leaked;
//! * the per-connection write queue is BOUNDED — a slow client that
//!   stops reading fills its own queue, gets a best-effort `Error`
//!   frame, and is disconnected; workers never block on it;
//! * a `Shutdown` frame is acked (`ShutdownAck`), then the server
//!   drains gracefully: stop accepting, wake every blocked reader,
//!   flush in-flight replies, join the engine.
//!
//! Every degradation moves time and availability, never bits: a served
//! prediction is always the batch-deterministic one, asserted in
//! `tests/serve_faults.rs`.
//!
//! A `Shutdown` frame stops the acceptor; the server then joins every
//! live connection, drains the engine, and returns the final
//! [`ShardReport`] — the same report in-process serving produces, which
//! is what lets CI assert socket/in-process bit-parity.

use super::shard::{Outcome, ShardReport, ShardedConfig, ShardedServer, SubmitError, Verdict};
use super::wire::{read_frame, write_frame, Message};
use super::RejectReason;
use crate::util::faults;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Where a server listens / a client connects.  Textual form is
/// `unix:/path/to.sock` for Unix-domain sockets, anything else is a
/// TCP `host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    Unix(std::path::PathBuf),
}

impl Endpoint {
    pub fn parse(s: &str) -> Endpoint {
        match s.strip_prefix("unix:") {
            Some(p) => Endpoint::Unix(std::path::PathBuf::from(p)),
            None => Endpoint::Tcp(s.to_string()),
        }
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "{a}"),
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Connection/acceptor resilience knobs (defaults read the `DSG_*` env
/// once at construction; see the README env table).
#[derive(Debug, Clone)]
pub struct ServerTuning {
    /// Read/idle deadline per connection (`DSG_CONN_IDLE_MS`, default
    /// 30 s): a peer sending nothing for this long is disconnected.
    pub idle_timeout: Duration,
    /// Socket write deadline (`DSG_CONN_WRITE_MS`, default 10 s): a
    /// single frame write blocked this long fails the writer.
    pub write_timeout: Duration,
    /// Bound on queued outbound frames per connection
    /// (`DSG_WRITE_QUEUE`, default 1024); overflow = slow client =>
    /// disconnect.
    pub write_queue: usize,
    /// Cap for the acceptor's doubling error backoff.
    pub accept_backoff_max: Duration,
}

fn env_ms(key: &str, default_ms: u64) -> Duration {
    let ms = std::env::var(key).ok().and_then(|s| s.parse::<u64>().ok()).unwrap_or(default_ms);
    Duration::from_millis(ms)
}

impl Default for ServerTuning {
    fn default() -> ServerTuning {
        ServerTuning {
            idle_timeout: env_ms("DSG_CONN_IDLE_MS", 30_000),
            write_timeout: env_ms("DSG_CONN_WRITE_MS", 10_000),
            write_queue: std::env::var("DSG_WRITE_QUEUE")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(1024)
                .max(1),
            accept_backoff_max: Duration::from_millis(500),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(on),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(on),
        }
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }
}

/// One accepted or dialed connection (either transport).
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> std::io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(on),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(on),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(t),
        }
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_write_timeout(t),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_write_timeout(t),
        }
    }

    fn shutdown(&self, how: std::net::Shutdown) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.shutdown(how),
            #[cfg(unix)]
            Conn::Unix(s) => s.shutdown(how),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// The socket-serving front-end: a bound listener plus a running
/// [`ShardedServer`].
pub struct WireServer {
    engine: Arc<ShardedServer>,
    listener: Listener,
    local: Endpoint,
    stop: Arc<AtomicBool>,
    tuning: ServerTuning,
}

impl WireServer {
    /// Bind `endpoint` and start the sharded engine behind it.  For TCP
    /// port 0 the resolved port is available via
    /// [`WireServer::local_endpoint`].  A pre-existing Unix socket path
    /// is replaced (stale sockets from a killed server would otherwise
    /// wedge restarts).
    pub fn bind<F>(endpoint: &Endpoint, cfg: ShardedConfig, forward: F) -> Result<WireServer>
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        Self::bind_tuned(endpoint, cfg, ServerTuning::default(), forward)
    }

    /// [`WireServer::bind`] with explicit [`ServerTuning`].
    pub fn bind_tuned<F>(
        endpoint: &Endpoint,
        cfg: ShardedConfig,
        tuning: ServerTuning,
        forward: F,
    ) -> Result<WireServer>
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        let (listener, local) = match endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
                let local = Endpoint::Tcp(l.local_addr()?.to_string());
                (Listener::Tcp(l), local)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {}", path.display()))?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(path) => {
                bail!("unix sockets are not supported on this platform: {}", path.display())
            }
        };
        let engine = Arc::new(ShardedServer::start(cfg, forward));
        Ok(WireServer {
            engine,
            listener,
            local,
            stop: Arc::new(AtomicBool::new(false)),
            tuning,
        })
    }

    /// The bound address (TCP port resolved if bound to port 0).
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.local
    }

    /// A flag that makes [`WireServer::run`] return after the current
    /// accept-poll tick (the in-band `Shutdown` frame sets the same
    /// flag).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept and serve connections until a `Shutdown` frame (or the
    /// stop handle) fires, then drain gracefully: stop accepting, wake
    /// every blocked reader (read-side shutdown), flush in-flight
    /// replies, join the engine, and return the merged report.
    ///
    /// The accept loop never dies to an accept error: transient
    /// failures (EMFILE fd exhaustion, EINTR, injected `accept`
    /// faults) are absorbed with a doubling backoff, counted in
    /// [`crate::metrics::RecoveryCounters`].
    pub fn run(self) -> Result<ShardReport> {
        self.listener.set_nonblocking(true).context("setting listener nonblocking")?;
        let registry: Arc<Mutex<HashMap<u64, Conn>>> = Arc::new(Mutex::new(HashMap::new()));
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_id = 0u64;
        let base_backoff = Duration::from_millis(10);
        let mut backoff = base_backoff;
        while !self.stop.load(Ordering::SeqCst) {
            let injected = faults::check("accept").is_some();
            let accepted = if injected {
                Err(faults::injected_error("accept"))
            } else {
                self.listener.accept()
            };
            match accepted {
                Ok(conn) => {
                    backoff = base_backoff;
                    conn.set_nonblocking(false).context("setting connection blocking")?;
                    crate::metrics::recovery().on_conn_opened();
                    let id = next_id;
                    next_id += 1;
                    if let Ok(clone) = conn.try_clone() {
                        registry.lock().unwrap().insert(id, clone);
                    }
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    let tuning = self.tuning.clone();
                    let reg = registry.clone();
                    conns.push(std::thread::spawn(move || {
                        // a torn connection only kills this handler
                        let _ = handle_connection(conn, &engine, &stop, &tuning);
                        reg.lock().unwrap().remove(&id);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    // transient (EMFILE, EINTR, injected): back off and
                    // keep listening — only the stop flag ends the loop
                    crate::metrics::recovery().on_accept_backoff();
                    crate::warn!("accept error (backing off {backoff:?}): {e}");
                    std::thread::sleep(backoff);
                    backoff = backoff.saturating_mul(2).min(self.tuning.accept_backoff_max);
                }
            }
            conns.retain(|h| !h.is_finished());
        }
        // graceful drain: wake every reader blocked in read() so
        // handlers exit promptly; their writers then flush whatever
        // replies are still in flight before the join below
        for (_, c) in registry.lock().unwrap().iter() {
            let _ = c.shutdown(std::net::Shutdown::Read);
        }
        for h in conns {
            let _ = h.join();
        }
        crate::metrics::recovery().on_drain();
        if let Endpoint::Unix(path) = &self.local {
            let _ = std::fs::remove_file(path);
        }
        let engine = Arc::try_unwrap(self.engine)
            .map_err(|_| anyhow::anyhow!("connection still holds the engine at shutdown"))?;
        Ok(engine.join())
    }
}

/// `true` when the error chain bottoms out in a read/write deadline
/// expiry (EAGAIN surfaces as `WouldBlock` on unix, `TimedOut`
/// elsewhere).
fn is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>()
            .map(|io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                )
            })
            .unwrap_or(false)
    })
}

/// Enqueue an outbound frame on the connection's BOUNDED write queue.
/// A full queue marks the connection slow (the reader disconnects it)
/// instead of blocking the caller — reply hooks run on engine workers,
/// and a slow client must never stall a worker.
fn queue_send(tx: &SyncSender<Message>, slow: &AtomicBool, msg: Message) {
    match tx.try_send(msg) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => slow.store(true, Ordering::SeqCst),
        Err(TrySendError::Disconnected(_)) => {}
    }
}

/// Serve one connection: read frames, submit requests, answer control
/// messages.  Returns when the peer closes, sends `Shutdown`, idles
/// past the read deadline, or overflows its write queue.
fn handle_connection(
    conn: Conn,
    engine: &Arc<ShardedServer>,
    stop: &Arc<AtomicBool>,
    tuning: &ServerTuning,
) -> Result<()> {
    conn.set_read_timeout(Some(tuning.idle_timeout)).context("setting read deadline")?;
    let writer_conn = conn.try_clone().context("cloning connection for writer")?;
    writer_conn
        .set_write_timeout(Some(tuning.write_timeout))
        .context("setting write deadline")?;
    let (tx, rx) = sync_channel::<Message>(tuning.write_queue);
    let slow = Arc::new(AtomicBool::new(false));
    let slow_w = slow.clone();
    let writer = std::thread::spawn(move || {
        let mut w = std::io::BufWriter::new(writer_conn);
        // exits when every sender (reader + outstanding reply hooks)
        // has dropped — i.e. after the last response for this
        // connection is on the wire
        while let Ok(msg) = rx.recv() {
            if faults::check("wire.write").is_some() {
                break;
            }
            if write_frame(&mut w, &msg).is_err() {
                break;
            }
        }
        if slow_w.load(Ordering::SeqCst) {
            // best-effort parting diagnosis for the slow client
            let _ = write_frame(
                &mut w,
                &Message::Error {
                    id: u64::MAX,
                    message: "write queue overflowed (slow client); disconnecting".into(),
                },
            );
        }
    });
    let mut r = std::io::BufReader::new(conn);
    let result = (|| -> Result<()> {
        loop {
            if slow.load(Ordering::SeqCst) {
                crate::metrics::recovery().on_disconnect_slow();
                bail!("write queue overflowed (slow client); disconnecting");
            }
            if faults::check("wire.read").is_some() {
                crate::metrics::recovery().on_disconnect_error();
                return Err(faults::injected_error("wire.read")).context("reading frame");
            }
            let frame = match read_frame(&mut r) {
                Ok(f) => f,
                Err(e) if is_timeout(&e) => {
                    if stop.load(Ordering::SeqCst) {
                        return Ok(()); // server draining; treat as closed
                    }
                    if slow.load(Ordering::SeqCst) {
                        crate::metrics::recovery().on_disconnect_slow();
                        bail!("write queue overflowed (slow client); disconnecting");
                    }
                    crate::metrics::recovery().on_disconnect_idle();
                    bail!("idle past the read deadline; disconnecting");
                }
                Err(e) => {
                    crate::metrics::recovery().on_disconnect_error();
                    return Err(e);
                }
            };
            let Some(msg) = frame else {
                return Ok(()); // clean EOF
            };
            match msg {
                Message::Request { id, image } => {
                    let reply_tx = tx.clone();
                    let reply_slow = slow.clone();
                    let reply = Box::new(move |o: Outcome| {
                        let msg = match o.verdict {
                            Verdict::Pred(p) => Message::Response {
                                id: o.id,
                                pred: p as u32,
                                latency_us: (o.latency * 1e6) as u32,
                            },
                            Verdict::Failed(m) => Message::Error { id: o.id, message: m },
                        };
                        queue_send(&reply_tx, &reply_slow, msg);
                    });
                    match engine.submit_replying(id, image, reply) {
                        Ok(()) => {}
                        Err(SubmitError::Rejected(rej)) => {
                            queue_send(&tx, &slow, Message::Reject { id, reason: rej.reason });
                        }
                        Err(SubmitError::BadRequest(m)) => {
                            queue_send(&tx, &slow, Message::Error { id, message: m });
                        }
                    }
                }
                Message::Ping { token } => {
                    queue_send(&tx, &slow, Message::Pong { token });
                }
                Message::Flush => engine.flush(),
                Message::Shutdown => {
                    // seal the forming batch so in-flight work drains,
                    // ack the shutdown, and stop the acceptor
                    engine.flush();
                    queue_send(&tx, &slow, Message::ShutdownAck);
                    stop.store(true, Ordering::SeqCst);
                    return Ok(());
                }
                other => {
                    crate::metrics::recovery().on_disconnect_error();
                    bail!("client sent a server-only message: {other:?}");
                }
            }
        }
    })();
    drop(tx);
    let _ = writer.join();
    result
}

/// What the load-generating client got back for one request.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientEvent {
    Response { id: u64, pred: u32, latency_us: u32 },
    Reject { id: u64, reason: RejectReason },
    Error { id: u64, message: String },
}

impl ClientEvent {
    pub fn id(&self) -> u64 {
        match self {
            ClientEvent::Response { id, .. }
            | ClientEvent::Reject { id, .. }
            | ClientEvent::Error { id, .. } => *id,
        }
    }
}

/// Result of one [`drive_load`] run.
#[derive(Debug)]
pub struct ClientRun {
    /// One terminal event per request, sorted by id.
    pub events: Vec<ClientEvent>,
    /// Client-measured round-trip seconds, indexed like `events`
    /// (measured from the FIRST send of each request).
    pub rtt: Vec<f64>,
    /// Wall-clock of the whole run, seconds.
    pub wall: f64,
    /// Requests re-sent after an `Overloaded` reject.
    pub retries: usize,
}

impl ClientRun {
    /// Predictions by id order, comparable to
    /// [`ShardReport::predictions`]: a reject or error maps to
    /// `usize::MAX` so divergence is loud.
    pub fn predictions(&self) -> Vec<usize> {
        self.events
            .iter()
            .map(|e| match e {
                ClientEvent::Response { pred, .. } => *pred as usize,
                _ => usize::MAX,
            })
            .collect()
    }

    pub fn served(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ClientEvent::Response { .. })).count()
    }

    pub fn rejected(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ClientEvent::Reject { .. })).count()
    }
}

/// Dial `endpoint`, retrying for up to `timeout` (a just-spawned server
/// may not be listening yet).
pub fn connect_retry(endpoint: &Endpoint, timeout: Duration) -> Result<()> {
    let t0 = Instant::now();
    loop {
        match dial(endpoint) {
            Ok(_) => return Ok(()),
            Err(e) if t0.elapsed() < timeout => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e).with_context(|| format!("connecting to {endpoint}")),
        }
    }
}

fn dial(endpoint: &Endpoint) -> Result<Conn> {
    match endpoint {
        Endpoint::Tcp(addr) => Ok(Conn::Tcp(TcpStream::connect(addr)?)),
        #[cfg(unix)]
        Endpoint::Unix(path) => Ok(Conn::Unix(UnixStream::connect(path)?)),
        #[cfg(not(unix))]
        Endpoint::Unix(path) => {
            bail!("unix sockets are not supported on this platform: {}", path.display())
        }
    }
}

/// Client-side behavior knobs for [`drive_load_with`].
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Send `Shutdown` (and wait for the `ShutdownAck`) at the end.
    pub shutdown_after: bool,
    /// Re-send rounds for requests rejected `Overloaded` (0 = report
    /// the reject as terminal, the pre-retry behavior).
    pub retries: usize,
    /// Base backoff between retry rounds; doubles per round, plus a
    /// seeded jitter so synchronized clients spread out.
    pub backoff: Duration,
    /// Jitter seed (client identity).
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            shutdown_after: false,
            retries: 0,
            backoff: Duration::from_millis(20),
            seed: 1,
        }
    }
}

/// Load-generating client: sends `images` as requests with ids
/// `0..images.len()`, a `Flush` after the last one (so a trailing
/// partial batch ships without waiting out the server's deadline),
/// collects one terminal event per request, and optionally sends
/// `Shutdown` before disconnecting.
pub fn drive_load(
    endpoint: &Endpoint,
    images: &[Vec<f32>],
    shutdown_after: bool,
) -> Result<ClientRun> {
    drive_load_with(endpoint, images, &ClientOptions { shutdown_after, ..Default::default() })
}

/// [`drive_load`] with retry: requests rejected `Overloaded` are
/// re-sent in rounds with doubling, jittered backoff — the client-side
/// half of graceful degradation (the server sheds load with explicit
/// rejects; a patient client turns them into throughput).  Re-sent
/// requests produce the same prediction a first-try admission would
/// have: batch composition changes, bits of each served answer do not
/// depend on which round admitted them... they depend only on the
/// batch, and every batch is computed by the same deterministic engine.
pub fn drive_load_with(
    endpoint: &Endpoint,
    images: &[Vec<f32>],
    opts: &ClientOptions,
) -> Result<ClientRun> {
    let t0 = Instant::now();
    let conn = dial(endpoint)?;
    let mut w = std::io::BufWriter::new(conn.try_clone().context("cloning client connection")?);
    let mut r = std::io::BufReader::new(conn);

    // handshake: a ping/pong proves both directions before load starts
    write_frame(&mut w, &Message::Ping { token: 0x5D6_0001 })?;
    match read_frame(&mut r)? {
        Some(Message::Pong { token: 0x5D6_0001 }) => {}
        other => bail!("handshake failed: expected pong, got {other:?}"),
    }

    let n = images.len();
    let mut final_events: Vec<Option<ClientEvent>> = (0..n).map(|_| None).collect();
    let mut send_times: Vec<Option<Instant>> = vec![None; n];
    let mut pending: Vec<u64> = (0..n as u64).collect();
    let mut retries_done = 0usize;
    let mut rng = crate::util::Pcg32::seeded(opts.seed ^ 0xC11E);
    let mut round = 0usize;
    while !pending.is_empty() {
        let expect: std::collections::HashSet<u64> = pending.iter().copied().collect();
        let k = pending.len();
        // the reader collects this round's k terminal events, then
        // hands the stream back for the next round
        let reader = std::thread::spawn(
            move || -> (Result<Vec<ClientEvent>>, std::io::BufReader<Conn>) {
                let mut events: Vec<ClientEvent> = Vec::with_capacity(k);
                let mut seen = std::collections::HashSet::new();
                let res = (|| -> Result<()> {
                    while events.len() < k {
                        let Some(msg) = read_frame(&mut r)? else {
                            bail!(
                                "server closed with {} of {k} responses delivered",
                                events.len()
                            );
                        };
                        let ev = match msg {
                            Message::Response { id, pred, latency_us } => {
                                ClientEvent::Response { id, pred, latency_us }
                            }
                            Message::Reject { id, reason } => ClientEvent::Reject { id, reason },
                            Message::Error { id, message } => ClientEvent::Error { id, message },
                            other => bail!("unexpected server message: {other:?}"),
                        };
                        ensure!(
                            expect.contains(&ev.id()),
                            "server answered unexpected request id {}",
                            ev.id()
                        );
                        ensure!(seen.insert(ev.id()), "duplicate terminal event for id {}", ev.id());
                        events.push(ev);
                    }
                    Ok(())
                })();
                (res.map(|()| events), r)
            },
        );
        for &id in &pending {
            let slot = &mut send_times[id as usize];
            if slot.is_none() {
                *slot = Some(Instant::now());
            }
            write_frame(&mut w, &Message::Request { id, image: images[id as usize].clone() })?;
        }
        write_frame(&mut w, &Message::Flush)?;
        let (res, r_back) =
            reader.join().map_err(|_| anyhow::anyhow!("client reader thread panicked"))?;
        r = r_back;
        let mut next: Vec<u64> = Vec::new();
        for ev in res? {
            match &ev {
                ClientEvent::Reject { reason: RejectReason::Overloaded, .. }
                    if round < opts.retries =>
                {
                    next.push(ev.id());
                }
                _ => final_events[ev.id() as usize] = Some(ev),
            }
        }
        if next.is_empty() {
            break;
        }
        retries_done += next.len();
        for _ in 0..next.len() {
            crate::metrics::recovery().on_client_retry();
        }
        // doubling backoff with seeded jitter in [0, backoff/2]
        let exp = opts.backoff.saturating_mul(1 << round.min(16) as u32);
        let jitter_us = (rng.next_u32() as u64) % (exp.as_micros().max(2) as u64 / 2);
        std::thread::sleep(exp + Duration::from_micros(jitter_us));
        pending = next;
        round += 1;
    }
    let recv_done = Instant::now();
    let events: Vec<ClientEvent> = final_events.into_iter().map(|e| e.unwrap()).collect();
    // per-id RTT upper bound: first-send time to end-of-run (exact
    // per-event stamps would need the reader to share the clock vector;
    // the serve bench measures its latencies server-side, so a bound
    // suffices here)
    let rtt: Vec<f64> = send_times
        .iter()
        .map(|s| recv_done.duration_since(s.unwrap()).as_secs_f64())
        .collect();

    if opts.shutdown_after {
        write_frame(&mut w, &Message::Shutdown)?;
        // wait for the ack — tolerant: an old server (or one whose
        // drain closed the socket first) just EOFs/errors
        loop {
            match read_frame(&mut r) {
                Ok(Some(Message::ShutdownAck)) | Ok(None) | Err(_) => break,
                Ok(Some(_)) => continue, // stale frame; keep waiting
            }
        }
    }
    Ok(ClientRun { events, rtt, wall: t0.elapsed().as_secs_f64(), retries: retries_done })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parse_roundtrip() {
        assert_eq!(Endpoint::parse("127.0.0.1:9000"), Endpoint::Tcp("127.0.0.1:9000".into()));
        assert_eq!(
            Endpoint::parse("unix:/tmp/dsg.sock"),
            Endpoint::Unix(std::path::PathBuf::from("/tmp/dsg.sock"))
        );
        assert_eq!(Endpoint::parse("unix:/tmp/dsg.sock").to_string(), "unix:/tmp/dsg.sock");
        assert_eq!(Endpoint::parse("0.0.0.0:0").to_string(), "0.0.0.0:0");
    }

    #[test]
    fn tuning_defaults_are_sane() {
        let t = ServerTuning::default();
        assert!(t.idle_timeout >= Duration::from_millis(1));
        assert!(t.write_timeout >= Duration::from_millis(1));
        assert!(t.write_queue >= 1);
        assert!(t.accept_backoff_max >= Duration::from_millis(10));
    }

    #[test]
    fn timeout_detection_sees_through_context() {
        let e = anyhow::Error::from(std::io::Error::new(
            std::io::ErrorKind::WouldBlock,
            "resource temporarily unavailable",
        ))
        .context("reading frame header")
        .context("outer");
        assert!(is_timeout(&e));
        let e2 = anyhow::anyhow!("plain");
        assert!(!is_timeout(&e2));
        let e3 = anyhow::Error::from(std::io::Error::other("boom")).context("reading");
        assert!(!is_timeout(&e3));
    }
}
