//! Socket front-end for the sharded serving engine: accepts
//! [`super::wire`] frames over TCP or a Unix-domain socket, feeds them
//! into a [`ShardedServer`], and streams responses back per connection.
//!
//! Topology: one acceptor loop ([`WireServer::run`]), and per
//! connection one reader thread (this thread) plus one writer thread
//! owning the outbound half.  The reader submits each `Request` with a
//! reply hook that encodes the [`Outcome`] and hands it to the writer's
//! channel — so responses stream back as their batches complete,
//! out-of-order by design (clients correlate by request id).  Admission
//! rejects and malformed-request errors are answered immediately from
//! the reader.
//!
//! A `Shutdown` frame stops the acceptor; the server then joins every
//! live connection, drains the engine, and returns the final
//! [`ShardReport`] — the same report in-process serving produces, which
//! is what lets CI assert socket/in-process bit-parity.

use super::shard::{Outcome, ShardReport, ShardedConfig, ShardedServer, SubmitError, Verdict};
use super::wire::{read_frame, write_frame, Message};
use super::RejectReason;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Where a server listens / a client connects.  Textual form is
/// `unix:/path/to.sock` for Unix-domain sockets, anything else is a
/// TCP `host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    Unix(std::path::PathBuf),
}

impl Endpoint {
    pub fn parse(s: &str) -> Endpoint {
        match s.strip_prefix("unix:") {
            Some(p) => Endpoint::Unix(std::path::PathBuf::from(p)),
            None => Endpoint::Tcp(s.to_string()),
        }
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "{a}"),
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(on),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(on),
        }
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }
}

/// One accepted or dialed connection (either transport).
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> std::io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(on),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(on),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// The socket-serving front-end: a bound listener plus a running
/// [`ShardedServer`].
pub struct WireServer {
    engine: Arc<ShardedServer>,
    listener: Listener,
    local: Endpoint,
    stop: Arc<AtomicBool>,
}

impl WireServer {
    /// Bind `endpoint` and start the sharded engine behind it.  For TCP
    /// port 0 the resolved port is available via
    /// [`WireServer::local_endpoint`].  A pre-existing Unix socket path
    /// is replaced (stale sockets from a killed server would otherwise
    /// wedge restarts).
    pub fn bind<F>(endpoint: &Endpoint, cfg: ShardedConfig, forward: F) -> Result<WireServer>
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        let (listener, local) = match endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
                let local = Endpoint::Tcp(l.local_addr()?.to_string());
                (Listener::Tcp(l), local)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix socket {}", path.display()))?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(path) => {
                bail!("unix sockets are not supported on this platform: {}", path.display())
            }
        };
        let engine = Arc::new(ShardedServer::start(cfg, forward));
        Ok(WireServer { engine, listener, local, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (TCP port resolved if bound to port 0).
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.local
    }

    /// A flag that makes [`WireServer::run`] return after the current
    /// accept-poll tick (the in-band `Shutdown` frame sets the same
    /// flag).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept and serve connections until a `Shutdown` frame (or the
    /// stop handle) fires, then join the connections, drain the engine,
    /// and return the merged report.
    pub fn run(self) -> Result<ShardReport> {
        self.listener.set_nonblocking(true).context("setting listener nonblocking")?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok(conn) => {
                    conn.set_nonblocking(false).context("setting connection blocking")?;
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    conns.push(std::thread::spawn(move || {
                        // a torn connection only kills this handler
                        let _ = handle_connection(conn, &engine, &stop);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
            conns.retain(|h| !h.is_finished());
        }
        for h in conns {
            let _ = h.join();
        }
        if let Endpoint::Unix(path) = &self.local {
            let _ = std::fs::remove_file(path);
        }
        let engine = Arc::try_unwrap(self.engine)
            .map_err(|_| anyhow::anyhow!("connection still holds the engine at shutdown"))?;
        Ok(engine.join())
    }
}

/// Serve one connection: read frames, submit requests, answer control
/// messages.  Returns when the peer closes or sends `Shutdown`.
fn handle_connection(conn: Conn, engine: &Arc<ShardedServer>, stop: &Arc<AtomicBool>) -> Result<()> {
    let writer_conn = conn.try_clone().context("cloning connection for writer")?;
    let (tx, rx) = channel::<Message>();
    let writer = std::thread::spawn(move || {
        let mut w = std::io::BufWriter::new(writer_conn);
        // exits when every sender (reader + outstanding reply hooks)
        // has dropped — i.e. after the last response for this
        // connection is on the wire
        while let Ok(msg) = rx.recv() {
            if write_frame(&mut w, &msg).is_err() {
                break;
            }
        }
    });
    let mut r = std::io::BufReader::new(conn);
    let result = (|| -> Result<()> {
        loop {
            let Some(msg) = read_frame(&mut r)? else {
                return Ok(()); // clean EOF
            };
            match msg {
                Message::Request { id, image } => {
                    let reply_tx = tx.clone();
                    let reply = Box::new(move |o: Outcome| {
                        let msg = match o.verdict {
                            Verdict::Pred(p) => Message::Response {
                                id: o.id,
                                pred: p as u32,
                                latency_us: (o.latency * 1e6) as u32,
                            },
                            Verdict::Failed(m) => Message::Error { id: o.id, message: m },
                        };
                        let _ = reply_tx.send(msg);
                    });
                    match engine.submit_replying(id, image, reply) {
                        Ok(()) => {}
                        Err(SubmitError::Rejected(rej)) => {
                            let _ = tx.send(Message::Reject { id, reason: rej.reason });
                        }
                        Err(SubmitError::BadRequest(m)) => {
                            let _ = tx.send(Message::Error { id, message: m });
                        }
                    }
                }
                Message::Ping { token } => {
                    let _ = tx.send(Message::Pong { token });
                }
                Message::Flush => engine.flush(),
                Message::Shutdown => {
                    stop.store(true, Ordering::SeqCst);
                    return Ok(());
                }
                other => bail!("client sent a server-only message: {other:?}"),
            }
        }
    })();
    drop(tx);
    let _ = writer.join();
    result
}

/// What the load-generating client got back for one request.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientEvent {
    Response { id: u64, pred: u32, latency_us: u32 },
    Reject { id: u64, reason: RejectReason },
    Error { id: u64, message: String },
}

impl ClientEvent {
    pub fn id(&self) -> u64 {
        match self {
            ClientEvent::Response { id, .. }
            | ClientEvent::Reject { id, .. }
            | ClientEvent::Error { id, .. } => *id,
        }
    }
}

/// Result of one [`drive_load`] run.
#[derive(Debug)]
pub struct ClientRun {
    /// One terminal event per request, sorted by id.
    pub events: Vec<ClientEvent>,
    /// Client-measured round-trip seconds, indexed like `events`.
    pub rtt: Vec<f64>,
    /// Wall-clock of the whole run, seconds.
    pub wall: f64,
}

impl ClientRun {
    /// Predictions by id order, comparable to
    /// [`ShardReport::predictions`]: a reject or error maps to
    /// `usize::MAX` so divergence is loud.
    pub fn predictions(&self) -> Vec<usize> {
        self.events
            .iter()
            .map(|e| match e {
                ClientEvent::Response { pred, .. } => *pred as usize,
                _ => usize::MAX,
            })
            .collect()
    }

    pub fn served(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ClientEvent::Response { .. })).count()
    }

    pub fn rejected(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ClientEvent::Reject { .. })).count()
    }
}

/// Dial `endpoint`, retrying for up to `timeout` (a just-spawned server
/// may not be listening yet).
pub fn connect_retry(endpoint: &Endpoint, timeout: Duration) -> Result<()> {
    let t0 = Instant::now();
    loop {
        match dial(endpoint) {
            Ok(_) => return Ok(()),
            Err(e) if t0.elapsed() < timeout => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e).with_context(|| format!("connecting to {endpoint}")),
        }
    }
}

fn dial(endpoint: &Endpoint) -> Result<Conn> {
    match endpoint {
        Endpoint::Tcp(addr) => Ok(Conn::Tcp(TcpStream::connect(addr)?)),
        #[cfg(unix)]
        Endpoint::Unix(path) => Ok(Conn::Unix(UnixStream::connect(path)?)),
        #[cfg(not(unix))]
        Endpoint::Unix(path) => {
            bail!("unix sockets are not supported on this platform: {}", path.display())
        }
    }
}

/// Load-generating client: sends `images` as requests with ids
/// `0..images.len()`, a `Flush` after the last one (so a trailing
/// partial batch ships without waiting out the server's deadline),
/// collects one terminal event per request, and optionally sends
/// `Shutdown` before disconnecting.
pub fn drive_load(
    endpoint: &Endpoint,
    images: &[Vec<f32>],
    shutdown_after: bool,
) -> Result<ClientRun> {
    let t0 = Instant::now();
    let conn = dial(endpoint)?;
    let mut w = std::io::BufWriter::new(conn.try_clone().context("cloning client connection")?);
    let mut r = std::io::BufReader::new(conn);

    // handshake: a ping/pong proves both directions before load starts
    write_frame(&mut w, &Message::Ping { token: 0x5D6_0001 })?;
    match read_frame(&mut r)? {
        Some(Message::Pong { token: 0x5D6_0001 }) => {}
        other => bail!("handshake failed: expected pong, got {other:?}"),
    }

    let n = images.len();
    let reader = std::thread::spawn(move || -> Result<Vec<ClientEvent>> {
        let mut events: Vec<Option<ClientEvent>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        while got < n {
            let Some(msg) = read_frame(&mut r)? else {
                bail!("server closed with {got} of {n} responses delivered");
            };
            let ev = match msg {
                Message::Response { id, pred, latency_us } => {
                    ClientEvent::Response { id, pred, latency_us }
                }
                Message::Reject { id, reason } => ClientEvent::Reject { id, reason },
                Message::Error { id, message } => ClientEvent::Error { id, message },
                other => bail!("unexpected server message: {other:?}"),
            };
            let id = ev.id() as usize;
            anyhow::ensure!(id < n, "server answered unknown request id {id}");
            anyhow::ensure!(events[id].is_none(), "duplicate terminal event for id {id}");
            events[id] = Some(ev);
            got += 1;
        }
        Ok(events.into_iter().map(|e| e.unwrap()).collect())
    });

    let mut send_times = Vec::with_capacity(n);
    for (id, img) in images.iter().enumerate() {
        send_times.push(Instant::now());
        write_frame(&mut w, &Message::Request { id: id as u64, image: img.clone() })?;
    }
    write_frame(&mut w, &Message::Flush)?;

    let events = reader
        .join()
        .map_err(|_| anyhow::anyhow!("client reader thread panicked"))??;
    let recv_done = Instant::now();
    // per-id RTT upper bound: send time to end-of-run (exact per-event
    // stamps would need the reader to share the clock vector; the serve
    // bench measures its latencies server-side, so a bound suffices
    // here)
    let rtt: Vec<f64> = send_times
        .iter()
        .map(|s| recv_done.duration_since(*s).as_secs_f64())
        .collect();

    if shutdown_after {
        write_frame(&mut w, &Message::Shutdown)?;
    }
    Ok(ClientRun { events, rtt, wall: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parse_roundtrip() {
        assert_eq!(Endpoint::parse("127.0.0.1:9000"), Endpoint::Tcp("127.0.0.1:9000".into()));
        assert_eq!(
            Endpoint::parse("unix:/tmp/dsg.sock"),
            Endpoint::Unix(std::path::PathBuf::from("/tmp/dsg.sock"))
        );
        assert_eq!(Endpoint::parse("unix:/tmp/dsg.sock").to_string(), "unix:/tmp/dsg.sock");
        assert_eq!(Endpoint::parse("0.0.0.0:0").to_string(), "0.0.0.0:0");
    }
}
