//! Sharded serving engine: per-worker block queues fed by a dispatcher,
//! with work stealing, admission control, and density-aware batch
//! shaping.
//!
//! This replaces the single `Mutex`+`Condvar` FIFO of
//! [`super::concurrent::ConcurrentServer`] as the production front-end
//! (the old server is retained as the single-queue baseline).  The
//! design splits the two jobs the old queue conflated:
//!
//! * **Batch formation** happens at *dispatch* time, not in the
//!   workers.  The submitting thread appends to one forming block;
//!   every `max_batch` requests it seals the block and pushes it to
//!   shard `seq % shards` round-robin.  Batch composition is therefore
//!   a pure function of arrival order — block `k` is requests
//!   `[k*B, (k+1)*B)` — for ANY shard count and ANY worker count.
//!   That is the crown-jewel invariant carried over from the single
//!   queue: predictions are bit-identical across `{shards} x {workers}`
//!   because batches (and with them the DSG shared-threshold masks)
//!   never change, only *where* and *when* they execute.
//! * **Batch execution** is per-shard: worker `w`'s home shard is
//!   `w % shards`; it drains home blocks FIFO (modulo density shaping,
//!   below) and steals the oldest block from the deepest foreign shard
//!   when home is empty.  Stealing moves a whole sealed block, so it
//!   can never re-mix requests across batches.
//!
//! **Admission control**: with `queue_cap > 0`, a submit whose
//! destination shard already holds `queue_cap` blocks is rejected with
//! an explicit [`Rejected`] error (counted per shard) instead of
//! growing the queue without bound.  Overload therefore degrades into
//! reported rejections with bounded queue delay, not an unbounded p99
//! cliff.
//!
//! **Density-aware batch shaping**: the dispatcher tags each sealed
//! block with the kernel path its measured input density selects (the
//! compound input-gather engages below
//! [`crate::sparse::parallel::compound_cutoff`]); workers prefer to run
//! consecutive blocks of the same bucket so one kernel path stays hot,
//! with a starvation guard that falls back to strict FIFO once the
//! oldest block has waited `4 * max_wait`.  Shaping reorders block
//! *execution*, never block *composition* — it moves time, never bits.
//!
//! Failure semantics: a `forward` that panics or errors fails only the
//! block that was in flight — the worker catches the unwind, reports a
//! [`Verdict::Failed`] per affected request, and keeps serving.  A dead
//! request is therefore impossible by construction: every admitted
//! request ends as exactly one [`Outcome`]; every refused one ends as a
//! [`Rejected`] error at the submit call.

use super::{argmax, assemble_padded_into, RejectReason, Rejected};
use crate::metrics::{LatencyHistogram, ShardCounters, ShardSnapshot};
use crate::util::faults;
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Static parameters of the sharded server.
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    /// Shard queues (requests are distributed block-round-robin).
    pub shards: usize,
    /// Worker threads; worker `w` is homed on shard `w % shards`.
    pub workers: usize,
    /// Full batch size (the model's fixed batch dimension).
    pub max_batch: usize,
    /// Flat pixels per request.
    pub input_elems: usize,
    /// Logits per sample.
    pub classes: usize,
    /// Deadline: an idle worker seals the partial forming block once
    /// its oldest request has waited this long (streaming path only —
    /// [`ShardedServer::serve_all`] never deadline-flushes).
    pub max_wait: Duration,
    /// Per-shard bound on queued blocks; `0` = unbounded (no admission
    /// control, nothing is ever rejected).
    pub queue_cap: usize,
    /// Tag blocks with their kernel-path bucket and let workers group
    /// same-bucket blocks (execution order only; bit-neutral).
    pub density_shaping: bool,
    /// Re-attempts of a failed batch forward before the failure is
    /// delivered to its requests.  The forward is a pure function of
    /// the assembled batch, so a retry is bit-identical when it
    /// succeeds — retries absorb transient faults, they never move
    /// bits.
    pub batch_retries: usize,
}

impl ShardedConfig {
    pub fn new(
        shards: usize,
        workers: usize,
        max_batch: usize,
        input_elems: usize,
        classes: usize,
    ) -> ShardedConfig {
        assert!(max_batch > 0 && input_elems > 0 && classes > 0);
        ShardedConfig {
            shards: shards.max(1),
            workers: workers.max(1),
            max_batch,
            input_elems,
            classes,
            max_wait: Duration::from_millis(5),
            queue_cap: 0,
            density_shaping: true,
            batch_retries: 1,
        }
    }

    pub fn with_max_wait(mut self, max_wait: Duration) -> ShardedConfig {
        self.max_wait = max_wait;
        self
    }

    /// Bound each shard at `cap` queued blocks (`0` = unbounded).
    pub fn with_queue_cap(mut self, cap: usize) -> ShardedConfig {
        self.queue_cap = cap;
        self
    }

    pub fn with_density_shaping(mut self, on: bool) -> ShardedConfig {
        self.density_shaping = on;
        self
    }

    /// Re-attempt a failed batch forward this many times (0 = fail
    /// fast).
    pub fn with_batch_retries(mut self, retries: usize) -> ShardedConfig {
        self.batch_retries = retries;
        self
    }
}

/// What happened to one admitted request.
#[derive(Debug, Clone)]
pub enum Verdict {
    /// Classified: the argmax of the request's logit row.
    Pred(usize),
    /// The batch containing this request failed (forward error or
    /// panic); the message is shared by every request of the batch.
    Failed(String),
}

/// Terminal record of one admitted request.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Caller-visible id (the wire request id, or the submit-order
    /// sequence number for in-process submits).
    pub id: u64,
    pub verdict: Verdict,
    /// Queue wait + compute, seconds.
    pub latency: f64,
    /// Forward duration of the containing batch, seconds.
    pub compute: f64,
}

/// Per-request completion hook (wire connections pass one; in-process
/// submits leave it `None` and collect from the final report).
pub type ReplyFn = Box<dyn FnOnce(Outcome) + Send>;

/// A malformed or refused submit.
#[derive(Debug)]
pub enum SubmitError {
    /// Refused by admission control or because the server is closing.
    Rejected(Rejected),
    /// The request itself is invalid (wrong pixel count).
    BadRequest(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected(r) => write!(f, "{r}"),
            SubmitError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct ShardRequest {
    /// Caller-visible id carried into the [`Outcome`].
    id: u64,
    image: Vec<f32>,
    enqueued: Instant,
    reply: Option<ReplyFn>,
}

/// A sealed batch: `reqs.len() <= max_batch` contiguous-arrival
/// requests plus the kernel-path bucket its input density selects.
struct Block {
    reqs: Vec<ShardRequest>,
    bucket: u8,
    /// Enqueue time of the oldest request (starvation guard).
    oldest: Instant,
}

struct Shard {
    q: Mutex<VecDeque<Block>>,
    counters: ShardCounters,
}

/// Forming-block state, owned by the dispatcher lock.
struct Dispatch {
    forming: Vec<ShardRequest>,
    /// Submit-order sequence (also the default request id).
    next_seq: u64,
    /// Sealed-block count; destination shard is `next_block % shards`.
    next_block: u64,
    closed: bool,
}

/// Epoch-counting wakeup: producers bump under the lock and notify;
/// consumers snapshot the epoch BEFORE scanning the queues and only
/// sleep if it has not moved since — no lost-wakeup window.
struct Notify {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Notify {
    fn bump(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Sleep (bounded by `timeout`) unless the epoch moved past `seen`.
    fn wait_if_unchanged(&self, seen: u64, timeout: Duration) {
        let g = self.epoch.lock().unwrap();
        if *g == seen {
            let _ = self.cv.wait_timeout(g, timeout).unwrap();
        }
    }
}

/// Per-worker accounting, merged into the final report.
#[derive(Default, Debug, Clone)]
pub struct ShardWorkerStats {
    pub served: usize,
    pub failed: usize,
    pub batches: usize,
    pub padded_slots: usize,
    /// Blocks this worker took from a foreign shard.
    pub stolen: usize,
    /// Batches that continued the previous batch's density bucket.
    pub bucket_runs: usize,
    /// Failed forward attempts that were re-run (transient faults
    /// absorbed without a client-visible failure).
    pub retries: usize,
    pub latency: LatencyHistogram,
    pub compute: LatencyHistogram,
}

impl ShardWorkerStats {
    fn merge(&mut self, o: &ShardWorkerStats) {
        self.served += o.served;
        self.failed += o.failed;
        self.batches += o.batches;
        self.padded_slots += o.padded_slots;
        self.stolen += o.stolen;
        self.bucket_runs += o.bucket_runs;
        self.retries += o.retries;
        self.latency.merge(&o.latency);
        self.compute.merge(&o.compute);
    }
}

/// Aggregated outcome of one sharded serving run.
#[derive(Debug)]
pub struct ShardReport {
    /// Outcomes of every collected (reply-less) request, sorted by id.
    pub outcomes: Vec<Outcome>,
    pub served: usize,
    pub failed: usize,
    /// Requests refused admission (never entered a block).
    pub rejected: u64,
    pub batches: usize,
    pub padded_slots: usize,
    pub stolen: usize,
    /// Batch-forward re-attempts across all workers.
    pub retries: usize,
    pub latency: LatencyHistogram,
    pub compute: LatencyHistogram,
    /// Wall-clock from server start to drain completion, seconds.
    pub wall: f64,
    pub per_shard: Vec<ShardSnapshot>,
    pub per_worker: Vec<ShardWorkerStats>,
}

impl ShardReport {
    pub fn throughput(&self) -> f64 {
        self.served as f64 / self.wall.max(1e-12)
    }

    /// Predictions of the collected outcomes in id order (the
    /// bit-exactness currency); a failed request maps to `usize::MAX`
    /// so a silent substitution can never pass an equality assert.
    pub fn predictions(&self) -> Vec<usize> {
        self.outcomes
            .iter()
            .map(|o| match o.verdict {
                Verdict::Pred(p) => p,
                Verdict::Failed(_) => usize::MAX,
            })
            .collect()
    }

    /// First failure message, if any batch failed.
    pub fn first_failure(&self) -> Option<&str> {
        self.outcomes.iter().find_map(|o| match &o.verdict {
            Verdict::Failed(m) => Some(m.as_str()),
            Verdict::Pred(_) => None,
        })
    }

    /// `Err` if any admitted request failed (rejections are NOT
    /// failures: they were answered at submit time).
    pub fn into_result(self) -> Result<ShardReport> {
        if self.failed > 0 {
            let msg = self.first_failure().unwrap_or("unknown").to_string();
            anyhow::bail!("{} of {} requests failed: {msg}", self.failed, self.failed + self.served);
        }
        Ok(self)
    }
}

struct Inner {
    cfg: ShardedConfig,
    shards: Vec<Shard>,
    dispatch: Mutex<Dispatch>,
    notify: Notify,
    collected: Mutex<Vec<Outcome>>,
    rejected: std::sync::atomic::AtomicU64,
}

/// The sharded multi-worker server.  [`ShardedServer::start`] spawns
/// the workers; [`ShardedServer::submit`] /
/// [`ShardedServer::submit_replying`] enqueue; [`ShardedServer::join`]
/// closes, drains, and returns the merged [`ShardReport`].
pub struct ShardedServer {
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<ShardWorkerStats>>,
    started: Instant,
}

impl ShardedServer {
    /// Spawn `cfg.workers` threads serving `forward` (flat padded batch
    /// of `max_batch * input_elems` -> flat `max_batch * classes`
    /// logits).  `forward` must tolerate concurrent calls.
    pub fn start<F>(cfg: ShardedConfig, forward: F) -> ShardedServer
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        Self::start_with(cfg, forward, Vec::new(), false)
    }

    /// Serve a fully pre-enqueued load and drain it to completion.
    ///
    /// Every request is dispatched into its block (and the queue
    /// closed) BEFORE the first worker spawns, so block composition is
    /// `[0..B), [B..2B), ...` by construction — no deadline flush can
    /// split it, for any shard or worker count.  This is the entry
    /// point behind every bit-exactness assertion.  `queue_cap` is
    /// ignored here (a pre-enqueued drain is not an overload).
    pub fn serve_all<F>(
        cfg: ShardedConfig,
        forward: F,
        images: impl IntoIterator<Item = Vec<f32>>,
    ) -> Result<ShardReport>
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        let srv = Self::start_with(cfg, forward, images.into_iter().collect(), true);
        srv.join().into_result()
    }

    fn start_with<F>(
        cfg: ShardedConfig,
        forward: F,
        preload: Vec<Vec<f32>>,
        close_after_preload: bool,
    ) -> ShardedServer
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        let cfg = ShardedConfig { shards: cfg.shards.max(1), workers: cfg.workers.max(1), ..cfg };
        let shards = (0..cfg.shards)
            .map(|_| Shard { q: Mutex::new(VecDeque::new()), counters: ShardCounters::new() })
            .collect();
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            shards,
            dispatch: Mutex::new(Dispatch {
                forming: Vec::new(),
                next_seq: 0,
                next_block: 0,
                closed: false,
            }),
            notify: Notify { epoch: Mutex::new(0), cv: Condvar::new() },
            collected: Mutex::new(Vec::new()),
            rejected: std::sync::atomic::AtomicU64::new(0),
        });
        let started = Instant::now();
        // preload (serve_all): dispatch + close BEFORE spawning, so the
        // blocks are sealed with no worker able to deadline-flush
        {
            let mut dis = inner.dispatch.lock().unwrap();
            for image in preload {
                debug_assert_eq!(image.len(), cfg.input_elems);
                let id = dis.next_seq;
                dis.next_seq += 1;
                dis.forming.push(ShardRequest { id, image, enqueued: started, reply: None });
                if dis.forming.len() == cfg.max_batch {
                    inner.seal_locked(&mut dis, true);
                }
            }
            if close_after_preload {
                dis.closed = true;
                inner.seal_locked(&mut dis, true);
            }
        }
        let forward = Arc::new(forward);
        let handles = (0..cfg.workers)
            .map(|w| {
                let inner = inner.clone();
                let forward = forward.clone();
                std::thread::spawn(move || worker_loop(&inner, forward.as_ref(), w))
            })
            .collect();
        ShardedServer { inner, handles, started }
    }

    /// Enqueue one in-process request (outcome collected in the final
    /// report); returns its id (= submit order).
    pub fn submit(&self, image: Vec<f32>) -> std::result::Result<u64, SubmitError> {
        self.inner.admit(None, image, None)
    }

    /// Enqueue one request with an explicit id and a completion hook
    /// (the wire path: the hook encodes and sends the response frame).
    pub fn submit_replying(
        &self,
        id: u64,
        image: Vec<f32>,
        reply: ReplyFn,
    ) -> std::result::Result<(), SubmitError> {
        self.inner.admit(Some(id), image, Some(reply)).map(|_| ())
    }

    /// Seal the partial forming block now instead of waiting for
    /// `max_wait` (the wire `Flush` message; also useful before a
    /// latency-sensitive quiesce).
    pub fn flush(&self) {
        let mut dis = self.inner.dispatch.lock().unwrap();
        self.inner.seal_locked(&mut dis, false);
    }

    /// Number of collected outcomes so far (progress/tests).
    pub fn completed(&self) -> usize {
        self.inner.collected.lock().unwrap().len()
    }

    /// Stop admitting, flush the forming block, and wake the workers.
    /// Idempotent; [`ShardedServer::join`] calls it.
    pub fn close(&self) {
        let mut dis = self.inner.dispatch.lock().unwrap();
        if !dis.closed {
            dis.closed = true;
            self.inner.seal_locked(&mut dis, false);
        }
        drop(dis);
        self.inner.notify.bump();
    }

    /// Close, drain every queued block, join the workers, and merge
    /// their accounting.  Batch failures are reported in the result
    /// (`failed` + per-outcome verdicts), never silently dropped.
    pub fn join(self) -> ShardReport {
        self.close();
        let mut total = ShardWorkerStats::default();
        let mut per_worker = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            // a worker thread can only die to a panic OUTSIDE the
            // catch_unwind (a bug, not a load condition); surface it as
            // a merged-stats no-op and let accounting show the hole
            if let Ok(stats) = h.join() {
                total.merge(&stats);
                per_worker.push(stats);
            }
        }
        let wall = self.started.elapsed().as_secs_f64();
        let mut outcomes = std::mem::take(&mut *self.inner.collected.lock().unwrap());
        outcomes.sort_by_key(|o| o.id);
        ShardReport {
            outcomes,
            served: total.served,
            failed: total.failed,
            rejected: self.inner.rejected.load(std::sync::atomic::Ordering::Relaxed),
            batches: total.batches,
            padded_slots: total.padded_slots,
            stolen: total.stolen,
            retries: total.retries,
            latency: total.latency,
            compute: total.compute,
            wall,
            per_shard: self.inner.shards.iter().map(|s| s.counters.snapshot()).collect(),
            per_worker,
        }
    }

    /// The configuration this server was started with.
    pub fn config(&self) -> &ShardedConfig {
        &self.inner.cfg
    }
}

impl Inner {
    /// Admission + dispatch: validate, apply the queue bound, append to
    /// the forming block, seal when full.
    fn admit(
        &self,
        id: Option<u64>,
        image: Vec<f32>,
        reply: Option<ReplyFn>,
    ) -> std::result::Result<u64, SubmitError> {
        if image.len() != self.cfg.input_elems {
            return Err(SubmitError::BadRequest(format!(
                "request has {} elems, expected {}",
                image.len(),
                self.cfg.input_elems
            )));
        }
        let mut dis = self.dispatch.lock().unwrap();
        if dis.closed {
            self.rejected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Err(SubmitError::Rejected(Rejected { reason: RejectReason::Closing }));
        }
        // bound check against the forming block's destination shard
        if self.cfg.queue_cap > 0 {
            let dest = (dis.next_block % self.cfg.shards as u64) as usize;
            if self.shards[dest].q.lock().unwrap().len() >= self.cfg.queue_cap {
                self.shards[dest].counters.on_reject();
                self.rejected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(SubmitError::Rejected(Rejected {
                    reason: RejectReason::Overloaded,
                }));
            }
        }
        let seq = dis.next_seq;
        dis.next_seq += 1;
        let id = id.unwrap_or(seq);
        dis.forming.push(ShardRequest { id, image, enqueued: Instant::now(), reply });
        if dis.forming.len() == self.cfg.max_batch {
            self.seal_locked(&mut dis, false);
        }
        Ok(id)
    }

    /// Seal the forming block (if any) onto its round-robin shard.
    /// `quiet` skips the notify (preload path: workers not spawned yet).
    fn seal_locked(&self, dis: &mut Dispatch, quiet: bool) {
        if dis.forming.is_empty() {
            return;
        }
        let reqs = std::mem::take(&mut dis.forming);
        let bucket = if self.cfg.density_shaping {
            density_bucket(&reqs)
        } else {
            0
        };
        let oldest = reqs[0].enqueued;
        let dest = (dis.next_block % self.cfg.shards as u64) as usize;
        dis.next_block += 1;
        self.shards[dest].q.lock().unwrap().push_back(Block { reqs, bucket, oldest });
        self.shards[dest].counters.on_enqueue();
        if !quiet {
            self.notify.bump();
        }
    }

    /// Pop the next block for a worker homed on `home`: home shard
    /// first (bucket-preferring), then steal the oldest block from the
    /// deepest foreign shard.  Returns `(block, was_stolen)`.
    fn take_block(&self, home: usize, prefer: Option<u8>) -> Option<(Block, bool)> {
        if let Some(b) = self.pop_shard(home, prefer) {
            return Some((b, false));
        }
        // steal from the deepest foreign shard (load balancing); takes
        // the OLDEST block so stealing also bounds queue delay
        let mut best: Option<(usize, usize)> = None;
        for (i, s) in self.shards.iter().enumerate() {
            if i == home {
                continue;
            }
            let d = s.q.lock().unwrap().len();
            if d > 0 && best.map_or(true, |(bd, _)| d > bd) {
                best = Some((d, i));
            }
        }
        let (_, victim) = best?;
        let b = self.shards[victim].q.lock().unwrap().pop_front()?;
        self.shards[victim].counters.on_take(true);
        Some((b, true))
    }

    /// Pop from one shard: same-bucket block if shaping prefers one and
    /// the front block is not starving, else strict FIFO.
    fn pop_shard(&self, idx: usize, prefer: Option<u8>) -> Option<Block> {
        let mut q = self.shards[idx].q.lock().unwrap();
        if q.is_empty() {
            return None;
        }
        let mut pick = 0usize;
        if let Some(p) = prefer {
            let starving = q[0].oldest.elapsed() >= self.cfg.max_wait * 4;
            if self.cfg.density_shaping && !starving && q[0].bucket != p {
                if let Some(pos) = q.iter().position(|b| b.bucket == p) {
                    pick = pos;
                }
            }
        }
        let b = q.remove(pick);
        drop(q);
        self.shards[idx].counters.on_take(false);
        b
    }

    fn queued_blocks(&self) -> usize {
        self.shards.iter().map(|s| s.q.lock().unwrap().len()).sum()
    }

    /// Execute one block: assemble, forward (panic-contained), deliver
    /// one [`Outcome`] per request.
    fn run_block<F>(&self, block: Block, forward: &F, xs: &mut Vec<f32>, stats: &mut ShardWorkerStats)
    where
        F: Fn(&[f32]) -> Result<Vec<f32>>,
    {
        let cfg = &self.cfg;
        let reqs = block.reqs;
        let assembled = assemble_padded_into(
            reqs.iter().map(|r| (r.id, r.image.as_slice())),
            cfg.max_batch,
            cfg.input_elems,
            xs,
        );
        let (compute, failure, logits) = match assembled {
            Ok(padded) => {
                stats.padded_slots += padded;
                let t0 = Instant::now();
                // the forward is a pure function of the (already
                // assembled, untouched) batch, so a failed attempt —
                // transient I/O, an injected fault, even a panic — can
                // be re-run bit-identically.  Assembly happens once.
                let mut attempt = 0usize;
                let (failure, logits) = loop {
                    let r = if faults::check("serve.worker_batch").is_some() {
                        Ok(Err(anyhow::Error::from(faults::injected_error("serve.worker_batch"))))
                    } else {
                        std::panic::catch_unwind(AssertUnwindSafe(|| forward(&xs[..])))
                    };
                    let failure = match r {
                        Ok(Ok(l)) if l.len() == cfg.max_batch * cfg.classes => break (None, l),
                        Ok(Ok(l)) => format!(
                            "forward returned {} logits, expected {}",
                            l.len(),
                            cfg.max_batch * cfg.classes
                        ),
                        Ok(Err(e)) => format!("forward failed: {e:#}"),
                        Err(p) => panic_message(&p),
                    };
                    if attempt < cfg.batch_retries {
                        attempt += 1;
                        stats.retries += 1;
                        crate::metrics::recovery().on_batch_retry();
                        continue;
                    }
                    break (Some(failure), Vec::new());
                };
                (t0.elapsed().as_secs_f64(), failure, logits)
            }
            Err(e) => (0.0, Some(format!("batch assembly failed: {e:#}")), Vec::new()),
        };
        stats.batches += 1;
        stats.compute.record(compute);
        let mut collected = Vec::new();
        for (i, r) in reqs.into_iter().enumerate() {
            let latency = r.enqueued.elapsed().as_secs_f64();
            let verdict = match &failure {
                None => {
                    let row = &logits[i * cfg.classes..(i + 1) * cfg.classes];
                    stats.served += 1;
                    Verdict::Pred(argmax(row))
                }
                Some(msg) => {
                    stats.failed += 1;
                    Verdict::Failed(msg.clone())
                }
            };
            stats.latency.record(latency);
            let outcome = Outcome { id: r.id, verdict, latency, compute };
            match r.reply {
                Some(f) => f(outcome),
                None => collected.push(outcome),
            }
        }
        if !collected.is_empty() {
            self.collected.lock().unwrap().extend(collected);
        }
    }
}

pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("forward panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("forward panicked: {s}")
    } else {
        "forward panicked".to_string()
    }
}

/// Kernel-path bucket of a block: `1` when the measured input density
/// (nnz fraction over every pixel of the block) is below the compound
/// dispatch cutoff — the same rule the engines apply per layer — else
/// `0` (dense path).
fn density_bucket(reqs: &[ShardRequest]) -> u8 {
    let mut nnz = 0usize;
    let mut total = 0usize;
    for r in reqs {
        total += r.image.len();
        nnz += r.image.iter().filter(|v| **v != 0.0).count();
    }
    if total == 0 {
        return 0;
    }
    let density = nnz as f32 / total as f32;
    u8::from(density < crate::sparse::parallel::compound_cutoff())
}

fn worker_loop<F>(inner: &Inner, forward: &F, wid: usize) -> ShardWorkerStats
where
    F: Fn(&[f32]) -> Result<Vec<f32>>,
{
    let cfg = &inner.cfg;
    let home = wid % cfg.shards;
    let mut stats = ShardWorkerStats::default();
    let mut last_bucket: Option<u8> = None;
    // one assembly buffer per worker, reused across every batch
    let mut xs: Vec<f32> = Vec::new();
    loop {
        // snapshot BEFORE scanning: a push after this bumps the epoch
        // and cancels the sleep below
        let seen = inner.notify.epoch();
        if let Some((block, stolen)) = inner.take_block(home, last_bucket) {
            if stolen {
                stats.stolen += 1;
            }
            if last_bucket == Some(block.bucket) {
                stats.bucket_runs += 1;
            }
            last_bucket = Some(block.bucket);
            inner.run_block(block, forward, &mut xs, &mut stats);
            continue;
        }
        // queues empty: deadline-flush an aging partial forming block,
        // exit when closed and drained, else sleep
        let dis = inner.dispatch.lock().unwrap();
        if !dis.forming.is_empty() {
            let age = dis.forming[0].enqueued.elapsed();
            if age >= cfg.max_wait {
                let mut dis = dis;
                inner.seal_locked(&mut dis, false);
                continue;
            }
            let remaining = cfg.max_wait - age;
            drop(dis);
            inner.notify.wait_if_unchanged(seen, remaining);
            continue;
        }
        if dis.closed {
            drop(dis);
            // closed + empty forming: blocks can no longer be created,
            // so an empty scan here is terminal
            if inner.queued_blocks() == 0 {
                return stats;
            }
            continue;
        }
        drop(dis);
        inner.notify.wait_if_unchanged(seen, cfg.max_wait.max(Duration::from_millis(1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// pred = round(first pixel), same rule as the other serve tests.
    fn fake_forward(batch: usize, classes: usize) -> impl Fn(&[f32]) -> Result<Vec<f32>> {
        move |xs: &[f32]| {
            let per = xs.len() / batch;
            let mut out = vec![0.0f32; batch * classes];
            for i in 0..batch {
                let c = (xs[i * per].round() as usize).min(classes - 1);
                out[i * classes + c] = 1.0;
            }
            Ok(out)
        }
    }

    fn images(n: usize, modulo: usize) -> Vec<Vec<f32>> {
        (0..n).map(|i| vec![(i % modulo) as f32; 4]).collect()
    }

    #[test]
    fn serve_all_preds_match_across_shards_and_workers() {
        let imgs = images(53, 5);
        let base = ShardedServer::serve_all(
            ShardedConfig::new(1, 1, 8, 4, 6),
            fake_forward(8, 6),
            imgs.clone(),
        )
        .unwrap();
        assert_eq!(base.served, 53);
        assert_eq!(base.batches, 7); // ceil(53/8)
        assert_eq!(base.padded_slots, 3);
        for (shards, workers) in [(2usize, 1usize), (2, 3), (4, 2), (3, 8)] {
            let got = ShardedServer::serve_all(
                ShardedConfig::new(shards, workers, 8, 4, 6),
                fake_forward(8, 6),
                imgs.clone(),
            )
            .unwrap();
            assert_eq!(got.served, 53);
            assert_eq!(got.batches, 7);
            assert_eq!(
                base.predictions(),
                got.predictions(),
                "{shards} shards x {workers} workers diverged"
            );
        }
    }

    #[test]
    fn outcomes_keep_fifo_ids() {
        let report = ShardedServer::serve_all(
            ShardedConfig::new(3, 4, 4, 4, 8),
            fake_forward(4, 8),
            images(97, 7),
        )
        .unwrap();
        assert_eq!(report.served, 97);
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64, "id order broken at {i}");
            assert!(matches!(o.verdict, Verdict::Pred(p) if p == i % 7));
        }
        // block accounting: every block fully padded
        assert_eq!(report.served + report.padded_slots, report.batches * 4);
        // per-shard counters: every enqueued block was taken
        let enq: u64 = report.per_shard.iter().map(|s| s.enqueued).sum();
        let taken: u64 = report.per_shard.iter().map(|s| s.taken()).sum();
        assert_eq!(enq, report.batches as u64);
        assert_eq!(taken, enq);
    }

    #[test]
    fn fewer_workers_than_shards_forces_stealing() {
        // 1 worker homed on shard 0 of 4: every block on shards 1-3 can
        // only complete by stealing
        let report = ShardedServer::serve_all(
            ShardedConfig::new(4, 1, 4, 4, 5),
            fake_forward(4, 5),
            images(32, 5), // 8 blocks round-robin -> 2 per shard
        )
        .unwrap();
        assert_eq!(report.served, 32);
        assert_eq!(report.stolen, 6, "blocks on shards 1..3 must be stolen");
        let stolen: u64 = report.per_shard.iter().map(|s| s.stolen).sum();
        assert_eq!(stolen, 6);
        assert_eq!(report.per_shard[0].stolen, 0);
    }

    #[test]
    fn density_shaping_moves_time_never_bits() {
        // mixed load: half dense images, half mostly-zero images
        let imgs: Vec<Vec<f32>> = (0..40)
            .map(|i| {
                if i % 2 == 0 {
                    vec![(i % 5) as f32 + 1.0; 8]
                } else {
                    let mut v = vec![0.0f32; 8];
                    v[0] = (i % 5) as f32;
                    v
                }
            })
            .collect();
        let on = ShardedServer::serve_all(
            ShardedConfig::new(2, 3, 4, 8, 6).with_density_shaping(true),
            fake_forward(4, 6),
            imgs.clone(),
        )
        .unwrap();
        let off = ShardedServer::serve_all(
            ShardedConfig::new(2, 3, 4, 8, 6).with_density_shaping(false),
            fake_forward(4, 6),
            imgs,
        )
        .unwrap();
        assert_eq!(on.predictions(), off.predictions());
        assert_eq!(on.served, 40);
        assert_eq!(on.failed, 0);
    }

    #[test]
    fn bounded_queue_rejects_explicitly_and_conserves_requests() {
        // no workers draining fast enough: block the single worker with
        // a slow forward, then burst far past capacity
        let cfg = ShardedConfig::new(2, 1, 2, 4, 5)
            .with_queue_cap(2)
            .with_max_wait(Duration::from_millis(1));
        let srv = ShardedServer::start(cfg, move |xs: &[f32]| {
            std::thread::sleep(Duration::from_millis(20));
            fake_forward(2, 5)(xs)
        });
        let mut admitted = 0usize;
        let mut rejected = 0usize;
        for i in 0..100usize {
            match srv.submit(vec![(i % 5) as f32; 4]) {
                Ok(_) => admitted += 1,
                Err(SubmitError::Rejected(r)) => {
                    assert_eq!(r.reason, RejectReason::Overloaded);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(rejected > 0, "burst past a 2-block cap must reject");
        let report = srv.join();
        // conservation: every request is exactly one of served/rejected
        assert_eq!(report.served, admitted);
        assert_eq!(report.rejected as usize, rejected);
        assert_eq!(report.failed, 0);
        let shard_rej: u64 = report.per_shard.iter().map(|s| s.rejected).sum();
        assert_eq!(shard_rej, rejected as u64);
    }

    #[test]
    fn submit_after_close_is_a_closing_reject() {
        let srv = ShardedServer::start(ShardedConfig::new(1, 1, 2, 4, 5), fake_forward(2, 5));
        srv.close();
        match srv.submit(vec![0.0; 4]) {
            Err(SubmitError::Rejected(r)) => assert_eq!(r.reason, RejectReason::Closing),
            other => panic!("expected Closing reject, got {other:?}"),
        }
        let report = srv.join();
        assert_eq!(report.served, 0);
        assert_eq!(report.rejected, 1);
    }

    #[test]
    fn bad_request_is_refused_at_submit() {
        let srv = ShardedServer::start(ShardedConfig::new(1, 1, 2, 4, 5), fake_forward(2, 5));
        match srv.submit(vec![0.0; 3]) {
            Err(SubmitError::BadRequest(m)) => assert!(m.contains("3 elems"), "{m}"),
            other => panic!("expected BadRequest, got {other:?}"),
        }
        let report = srv.join();
        assert_eq!(report.served, 0);
        assert_eq!(report.rejected, 0);
    }

    #[test]
    fn panicking_batch_reports_failed_outcomes() {
        // poison pixel 3.0 panics its batch; everything else serves.
        // images: 0,1,2,3(poison),4,... batch 2 -> block [2,3] fails
        let forward = move |xs: &[f32]| -> Result<Vec<f32>> {
            assert!(!xs.contains(&3.0), "poison batch");
            fake_forward(2, 10)(xs)
        };
        let srv = ShardedServer::start_with(
            ShardedConfig::new(2, 2, 2, 4, 10),
            forward,
            images(10, 10),
            true,
        );
        let report = srv.join();
        // block [2,3] contains the poison pixel 3.0 -> 2 failed
        assert_eq!(report.failed, 2);
        assert_eq!(report.served, 8);
        assert_eq!(report.outcomes.len(), 10);
        for o in &report.outcomes {
            match (&o.verdict, o.id) {
                (Verdict::Failed(m), 2 | 3) => assert!(m.contains("panicked"), "{m}"),
                (Verdict::Pred(p), id) => assert_eq!(*p as u64, id % 10),
                (v, id) => panic!("unexpected verdict {v:?} for id {id}"),
            }
        }
        assert!(report.first_failure().is_some());
    }

    #[test]
    fn forward_error_reports_failed_not_hang() {
        let report = ShardedServer::serve_all(
            ShardedConfig::new(1, 1, 4, 4, 5),
            |_: &[f32]| anyhow::bail!("boom"),
            images(4, 5),
        );
        let err = report.unwrap_err().to_string();
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn streaming_flush_ships_partial_block() {
        let cfg = ShardedConfig::new(2, 2, 8, 4, 5).with_max_wait(Duration::from_secs(60));
        let srv = ShardedServer::start(cfg, fake_forward(8, 5));
        for i in 0..3usize {
            srv.submit(vec![(i % 5) as f32; 4]).unwrap();
        }
        // a 60s deadline would stall the partial block; flush ships it
        srv.flush();
        let t0 = Instant::now();
        while srv.completed() < 3 {
            assert!(t0.elapsed() < Duration::from_secs(5), "flush never shipped the block");
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = srv.join();
        assert_eq!(report.served, 3);
        assert_eq!(report.batches, 1);
        assert_eq!(report.padded_slots, 5);
    }

    #[test]
    fn streaming_deadline_flush_fires() {
        let cfg = ShardedConfig::new(1, 1, 64, 4, 5).with_max_wait(Duration::from_millis(15));
        let srv = ShardedServer::start(cfg, fake_forward(64, 5));
        srv.submit(vec![1.0; 4]).unwrap();
        srv.submit(vec![2.0; 4]).unwrap();
        let t0 = Instant::now();
        while srv.completed() < 2 {
            assert!(t0.elapsed() < Duration::from_secs(5), "deadline flush never fired");
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = srv.join();
        assert_eq!(report.served, 2);
        assert_eq!(report.predictions(), vec![1, 2]);
    }

    #[test]
    fn empty_server_joins_cleanly() {
        let srv = ShardedServer::start(ShardedConfig::new(4, 4, 8, 4, 5), fake_forward(8, 5));
        let report = srv.join();
        assert_eq!(report.served, 0);
        assert_eq!(report.batches, 0);
        assert!(report.outcomes.is_empty());
    }

    #[test]
    fn reply_hook_receives_outcomes_instead_of_collection() {
        let srv = ShardedServer::start(
            ShardedConfig::new(1, 1, 2, 4, 5).with_max_wait(Duration::from_millis(1)),
            fake_forward(2, 5),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..4u64 {
            let tx = tx.clone();
            srv.submit_replying(
                100 + i,
                vec![(i % 5) as f32; 4],
                Box::new(move |o| {
                    let _ = tx.send(o);
                }),
            )
            .unwrap();
        }
        drop(tx);
        let report = srv.join();
        assert_eq!(report.served, 4);
        assert!(report.outcomes.is_empty(), "replied outcomes must not be collected");
        let mut got: Vec<Outcome> = rx.iter().collect();
        got.sort_by_key(|o| o.id);
        assert_eq!(got.len(), 4);
        for (i, o) in got.iter().enumerate() {
            assert_eq!(o.id, 100 + i as u64);
            assert!(matches!(o.verdict, Verdict::Pred(p) if p == i % 5));
        }
    }

    #[test]
    fn density_bucket_splits_on_cutoff() {
        let dense = vec![ShardRequest {
            id: 0,
            image: vec![1.0; 8],
            enqueued: Instant::now(),
            reply: None,
        }];
        let sparse = vec![ShardRequest {
            id: 0,
            image: vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0],
            enqueued: Instant::now(),
            reply: None,
        }];
        assert_eq!(super::density_bucket(&dense), 0);
        assert_eq!(super::density_bucket(&sparse), 1);
    }
}
