//! Batched-inference serving substrate: request queue, dynamic batcher,
//! and latency accounting over any forward function (HLO-backed
//! `Trainer::forward` or the native engine).
//!
//! DSG's fixed-shape artifacts want full batches; the batcher assembles
//! them from a FIFO of single-image requests, padding the final partial
//! batch (padded rows are computed but their results dropped — the same
//! strategy the eval path uses).  Single-threaded pump by design: the
//! PJRT CPU client is not Sync and determinism matters more than
//! concurrency on this testbed.

use std::collections::VecDeque;
use std::time::Instant;

/// A single classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    /// queue wait + compute, seconds
    pub latency: f64,
    /// compute-only share
    pub compute: f64,
}

/// FIFO request queue with id assignment.
#[derive(Default)]
pub struct Queue {
    q: VecDeque<Request>,
    next_id: u64,
}

impl Queue {
    pub fn new() -> Queue {
        Queue::default()
    }

    pub fn push(&mut self, image: Vec<f32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.q.push_back(Request { id, image, enqueued: Instant::now() });
        id
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    fn take(&mut self, n: usize) -> Vec<Request> {
        let n = n.min(self.q.len());
        self.q.drain(..n).collect()
    }
}

/// Serving statistics.
#[derive(Default, Debug, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub latencies: Vec<f64>,
}

impl ServeStats {
    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut xs = self.latencies.clone();
        xs.sort_by(|a, b| a.total_cmp(b));
        let idx = ((xs.len() - 1) as f64 * p).round() as usize;
        xs[idx]
    }

    pub fn throughput(&self, wall_secs: f64) -> f64 {
        self.served as f64 / wall_secs.max(1e-12)
    }
}

/// The dynamic batcher + pump.
pub struct Batcher {
    pub batch_size: usize,
    pub input_elems: usize,
    pub classes: usize,
    pub stats: ServeStats,
}

impl Batcher {
    pub fn new(batch_size: usize, input_elems: usize, classes: usize) -> Batcher {
        assert!(batch_size > 0 && input_elems > 0 && classes > 0);
        Batcher { batch_size, input_elems, classes, stats: ServeStats::default() }
    }

    /// Drain the queue through `forward` (flat batch -> flat logits).
    /// Returns responses in completion order.
    pub fn pump(
        &mut self,
        queue: &mut Queue,
        mut forward: impl FnMut(&[f32]) -> anyhow::Result<Vec<f32>>,
    ) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        while !queue.is_empty() {
            let reqs = queue.take(self.batch_size);
            let valid = reqs.len();
            let mut xs = Vec::with_capacity(self.batch_size * self.input_elems);
            for r in &reqs {
                anyhow::ensure!(
                    r.image.len() == self.input_elems,
                    "request {} has {} elems, expected {}",
                    r.id,
                    r.image.len(),
                    self.input_elems
                );
                xs.extend_from_slice(&r.image);
            }
            // pad to a full batch by repeating the first image
            for _ in valid..self.batch_size {
                xs.extend_from_slice(&reqs[0].image);
                self.stats.padded_slots += 1;
            }
            let t0 = Instant::now();
            let logits = forward(&xs)?;
            let compute = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                logits.len() == self.batch_size * self.classes,
                "forward returned {} logits, expected {}",
                logits.len(),
                self.batch_size * self.classes
            );
            for (i, r) in reqs.into_iter().enumerate() {
                let row = &logits[i * self.classes..(i + 1) * self.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                let latency = r.enqueued.elapsed().as_secs_f64();
                self.stats.served += 1;
                self.stats.latencies.push(latency);
                out.push(Response { id: r.id, pred, latency, compute });
            }
            self.stats.batches += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_forward(batch: usize, classes: usize) -> impl FnMut(&[f32]) -> anyhow::Result<Vec<f32>> {
        move |xs: &[f32]| {
            // predict class = round(first pixel) for testability
            let per = xs.len() / batch;
            let mut out = vec![0.0f32; batch * classes];
            for i in 0..batch {
                let c = (xs[i * per].round() as usize).min(classes - 1);
                out[i * classes + c] = 1.0;
            }
            Ok(out)
        }
    }

    #[test]
    fn pump_serves_all_and_pads() {
        let mut q = Queue::new();
        for i in 0..10 {
            q.push(vec![i as f32 % 3.0; 4]);
        }
        let mut b = Batcher::new(4, 4, 5);
        let rs = b.pump(&mut q, fake_forward(4, 5)).unwrap();
        assert_eq!(rs.len(), 10);
        assert!(q.is_empty());
        assert_eq!(b.stats.batches, 3);
        assert_eq!(b.stats.padded_slots, 2); // last batch had 2 valid
        // predictions match the fake rule
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.pred, i % 3, "req {i}");
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn rejects_wrong_image_size() {
        let mut q = Queue::new();
        q.push(vec![0.0; 3]);
        let mut b = Batcher::new(2, 4, 5);
        assert!(b.pump(&mut q, fake_forward(2, 5)).is_err());
    }

    #[test]
    fn rejects_wrong_logit_count() {
        let mut q = Queue::new();
        q.push(vec![0.0; 4]);
        let mut b = Batcher::new(2, 4, 5);
        let r = b.pump(&mut q, |_| Ok(vec![0.0; 3]));
        assert!(r.is_err());
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.latencies = vec![0.001, 0.002, 0.003, 0.004, 0.100];
        assert_eq!(s.percentile(0.0), 0.001);
        assert_eq!(s.percentile(0.5), 0.003);
        assert_eq!(s.percentile(1.0), 0.100);
        s.served = 5;
        assert!((s.throughput(1.0) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn queue_fifo_ids() {
        let mut q = Queue::new();
        let a = q.push(vec![1.0]);
        let b = q.push(vec![2.0]);
        assert_eq!((a, b), (0, 1));
        let taken = q.take(1);
        assert_eq!(taken[0].id, 0);
        assert_eq!(q.len(), 1);
    }
}
