//! Batched-inference serving subsystem: request queue, dynamic batcher,
//! concurrent worker pool, and latency accounting over any forward
//! function (the native engine, a synthetic model, or the HLO-backed
//! `Trainer::forward`).
//!
//! DSG's fixed-shape artifacts want full batches; the batcher assembles
//! them from a FIFO of single-image requests, padding the final partial
//! batch (padded rows are computed but their results dropped — the same
//! strategy the eval path uses).
//!
//! Three execution substrates share those semantics:
//!
//! * [`Batcher`] — the original single-threaded pump, retained as the
//!   determinism baseline and for the PJRT path (the CPU client is not
//!   `Sync`).
//! * [`concurrent::ConcurrentServer`] — a shared `Mutex`+`Condvar`
//!   request queue feeding N worker threads, each draining FIFO batches
//!   with a deadline-based flush (`max_batch` + `max_wait`).  Workers
//!   aggregate per-request latency/compute into
//!   [`crate::metrics::LatencyHistogram`]s that merge at shutdown.
//!   Because batches are always contiguous FIFO chunks and the parallel
//!   engines are bit-exact under any thread budget, a pre-enqueued load
//!   (`ConcurrentServer::serve_all`) yields predictions identical for
//!   any worker count — by construction, not by timing.
//! * [`shard::ShardedServer`] — the production front-end: a dispatcher
//!   seals contiguous FIFO blocks and distributes them round-robin over
//!   per-shard queues; workers drain their home shard and steal whole
//!   blocks when idle.  Adds admission control (bounded queues with
//!   explicit [`Rejected`] responses), density-aware batch shaping, and
//!   per-shard [`crate::metrics::ShardCounters`].  Batch composition is
//!   a pure function of arrival order, so the bit-exactness guarantee
//!   extends to any shard count as well.
//!
//! The sharded engine is externally drivable: [`wire`] defines a
//! length-prefixed binary protocol (spec: `docs/PROTOCOL.md`) and
//! [`server`] serves it over TCP or a Unix socket.

pub mod concurrent;
pub mod server;
pub mod shard;
pub mod synth;
pub mod wire;

pub use concurrent::{ConcurrentServer, ServeReport, ServerConfig};
pub use server::{ClientOptions, ClientRun, Endpoint, ServerTuning, WireServer};
pub use shard::{Outcome, ShardReport, ShardedConfig, ShardedServer, SubmitError, Verdict};
pub use synth::SynthModel;

/// Why a request was refused admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The destination shard queue is at `queue_cap`.
    Overloaded,
    /// The server has stopped admitting (shutdown in progress).
    Closing,
}

impl RejectReason {
    /// Stable wire encoding (see `docs/PROTOCOL.md`).
    pub fn code(self) -> u8 {
        match self {
            RejectReason::Overloaded => 1,
            RejectReason::Closing => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<RejectReason> {
        match c {
            1 => Some(RejectReason::Overloaded),
            2 => Some(RejectReason::Closing),
            _ => None,
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::Overloaded => write!(f, "overloaded"),
            RejectReason::Closing => write!(f, "closing"),
        }
    }
}

/// Admission-control refusal: the request never entered a batch and
/// will never produce a response, so the caller must handle it NOW
/// (the wire server answers with a reject frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rejected {
    pub reason: RejectReason,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request rejected: {}", self.reason)
    }
}

impl std::error::Error for Rejected {}

use crate::metrics::LatencyHistogram;
use std::collections::VecDeque;
use std::time::Instant;

/// A single classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub pred: usize,
    /// queue wait + compute, seconds
    pub latency: f64,
    /// compute-only share
    pub compute: f64,
}

/// FIFO request queue with id assignment.
#[derive(Default)]
pub struct Queue {
    q: VecDeque<Request>,
    next_id: u64,
}

impl Queue {
    pub fn new() -> Queue {
        Queue::default()
    }

    pub fn push(&mut self, image: Vec<f32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.q.push_back(Request { id, image, enqueued: Instant::now() });
        id
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    fn take(&mut self, n: usize) -> Vec<Request> {
        let n = n.min(self.q.len());
        self.q.drain(..n).collect()
    }
}

/// Serving statistics (exact latencies plus the log-bucketed histogram).
#[derive(Default, Debug, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub latencies: Vec<f64>,
    pub hist: LatencyHistogram,
}

impl ServeStats {
    /// Exact percentile (nearest-rank, ceil convention: the smallest
    /// sample with at least `p` of the distribution at or below it).
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// Several percentiles from ONE sort of the latency vector — use
    /// this for reports instead of calling [`ServeStats::percentile`]
    /// per point (which re-sorts every time).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.latencies.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut xs = self.latencies.clone();
        xs.sort_by(|a, b| a.total_cmp(b));
        ps.iter().map(|&p| xs[percentile_index(xs.len(), p)]).collect()
    }

    pub fn throughput(&self, wall_secs: f64) -> f64 {
        self.served as f64 / wall_secs.max(1e-12)
    }
}

/// Ceil-convention nearest-rank index into `n` ascending samples: the
/// rank-`ceil(p*n)` sample (1-based), so p50 of two samples is the LOWER
/// one and p95 of 100 samples is the 95th smallest — `.round()` here
/// used to round half-up and read one rank too high at exact midpoints.
pub(crate) fn percentile_index(n: usize, p: f64) -> usize {
    debug_assert!(n > 0);
    let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    rank.clamp(1, n) - 1
}

/// Assemble one padded batch from `reqs` (flat row-major pixels).  The
/// partial tail is padded by repeating the first image; returns the
/// number of padded slots.  Shared by the baseline pump and the
/// concurrent workers so both substrates batch identically.
pub(crate) fn assemble_batch(
    reqs: &[Request],
    batch_size: usize,
    input_elems: usize,
) -> anyhow::Result<(Vec<f32>, usize)> {
    let mut xs = Vec::new();
    let padded = assemble_batch_into(reqs, batch_size, input_elems, &mut xs)?;
    Ok((xs, padded))
}

/// [`assemble_batch`] into a caller-owned buffer, so a serve worker
/// reuses one allocation across every batch it ever assembles.
pub(crate) fn assemble_batch_into(
    reqs: &[Request],
    batch_size: usize,
    input_elems: usize,
    xs: &mut Vec<f32>,
) -> anyhow::Result<usize> {
    assemble_padded_into(
        reqs.iter().map(|r| (r.id, r.image.as_slice())),
        batch_size,
        input_elems,
        xs,
    )
}

/// Request-shape-agnostic batch assembler shared by every serving
/// substrate: lays `rows` out row-major, pads the tail by repeating the
/// FIRST row (`extend_from_within`, no extra allocation), and returns
/// the padded-slot count.  All substrates batching through one function
/// is what keeps their padding semantics — and hence their DSG masks —
/// bit-identical.
pub(crate) fn assemble_padded_into<'a>(
    rows: impl ExactSizeIterator<Item = (u64, &'a [f32])>,
    batch_size: usize,
    input_elems: usize,
    xs: &mut Vec<f32>,
) -> anyhow::Result<usize> {
    let n = rows.len();
    anyhow::ensure!(n > 0, "cannot assemble an empty batch");
    anyhow::ensure!(
        n <= batch_size,
        "cannot assemble {n} requests into a batch of {batch_size}"
    );
    xs.clear();
    xs.reserve(batch_size * input_elems);
    for (id, row) in rows {
        anyhow::ensure!(
            row.len() == input_elems,
            "request {id} has {} elems, expected {input_elems}",
            row.len()
        );
        xs.extend_from_slice(row);
    }
    let padded = batch_size - n;
    for _ in 0..padded {
        xs.extend_from_within(0..input_elems);
    }
    Ok(padded)
}

/// Argmax of one logit row.
pub(crate) fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(j, _)| j)
        .unwrap_or(0)
}

/// The dynamic batcher + single-threaded pump (determinism baseline).
pub struct Batcher {
    pub batch_size: usize,
    pub input_elems: usize,
    pub classes: usize,
    pub stats: ServeStats,
}

impl Batcher {
    pub fn new(batch_size: usize, input_elems: usize, classes: usize) -> Batcher {
        assert!(batch_size > 0 && input_elems > 0 && classes > 0);
        Batcher { batch_size, input_elems, classes, stats: ServeStats::default() }
    }

    /// Drain the queue through `forward` (flat batch -> flat logits).
    /// Returns responses in completion order.
    pub fn pump(
        &mut self,
        queue: &mut Queue,
        mut forward: impl FnMut(&[f32]) -> anyhow::Result<Vec<f32>>,
    ) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        while !queue.is_empty() {
            let reqs = queue.take(self.batch_size);
            let (xs, padded) = assemble_batch(&reqs, self.batch_size, self.input_elems)?;
            self.stats.padded_slots += padded;
            let t0 = Instant::now();
            let logits = forward(&xs)?;
            let compute = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                logits.len() == self.batch_size * self.classes,
                "forward returned {} logits, expected {}",
                logits.len(),
                self.batch_size * self.classes
            );
            for (i, r) in reqs.into_iter().enumerate() {
                let row = &logits[i * self.classes..(i + 1) * self.classes];
                let pred = argmax(row);
                let latency = r.enqueued.elapsed().as_secs_f64();
                self.stats.served += 1;
                self.stats.latencies.push(latency);
                self.stats.hist.record(latency);
                out.push(Response { id: r.id, pred, latency, compute });
            }
            self.stats.batches += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_forward(batch: usize, classes: usize) -> impl FnMut(&[f32]) -> anyhow::Result<Vec<f32>> {
        move |xs: &[f32]| {
            // predict class = round(first pixel) for testability
            let per = xs.len() / batch;
            let mut out = vec![0.0f32; batch * classes];
            for i in 0..batch {
                let c = (xs[i * per].round() as usize).min(classes - 1);
                out[i * classes + c] = 1.0;
            }
            Ok(out)
        }
    }

    #[test]
    fn pump_serves_all_and_pads() {
        let mut q = Queue::new();
        for i in 0..10 {
            q.push(vec![i as f32 % 3.0; 4]);
        }
        let mut b = Batcher::new(4, 4, 5);
        let rs = b.pump(&mut q, fake_forward(4, 5)).unwrap();
        assert_eq!(rs.len(), 10);
        assert!(q.is_empty());
        assert_eq!(b.stats.batches, 3);
        assert_eq!(b.stats.padded_slots, 2); // last batch had 2 valid
        assert_eq!(b.stats.hist.count(), 10);
        // predictions match the fake rule
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.pred, i % 3, "req {i}");
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn rejects_wrong_image_size() {
        let mut q = Queue::new();
        q.push(vec![0.0; 3]);
        let mut b = Batcher::new(2, 4, 5);
        assert!(b.pump(&mut q, fake_forward(2, 5)).is_err());
    }

    #[test]
    fn rejects_wrong_logit_count() {
        let mut q = Queue::new();
        q.push(vec![0.0; 4]);
        let mut b = Batcher::new(2, 4, 5);
        let r = b.pump(&mut q, |_| Ok(vec![0.0; 3]));
        assert!(r.is_err());
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.latencies = vec![0.001, 0.002, 0.003, 0.004, 0.100];
        assert_eq!(s.percentile(0.0), 0.001);
        assert_eq!(s.percentile(0.5), 0.003);
        assert_eq!(s.percentile(1.0), 0.100);
        // one sort serving many points agrees with the per-point calls
        assert_eq!(s.percentiles(&[0.0, 0.5, 1.0]), vec![0.001, 0.003, 0.100]);
        s.served = 5;
        assert!((s.throughput(1.0) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_ceil_rank_midpoints() {
        // p50 of two samples is the LOWER one (the old .round() read max)
        let mut s = ServeStats::default();
        s.latencies = vec![2.0, 1.0];
        assert_eq!(s.percentile(0.5), 1.0);
        // p95 of 100 samples = the 95th smallest (index 94)
        assert_eq!(percentile_index(100, 0.95), 94);
        assert_eq!(percentile_index(2, 0.5), 0);
        assert_eq!(percentile_index(1, 0.5), 0);
        assert_eq!(percentile_index(10, 0.0), 0);
        assert_eq!(percentile_index(10, 1.0), 9);
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile_index(10, 1.5), 9);
        assert_eq!(percentile_index(10, -0.5), 0);
        // empty stats stay all-zero
        assert_eq!(ServeStats::default().percentiles(&[0.5, 0.9]), vec![0.0, 0.0]);
    }

    #[test]
    fn assemble_rejects_oversized_batch() {
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request { id: i, image: vec![i as f32; 2], enqueued: Instant::now() })
            .collect();
        // more requests than batch slots must be a clean error, not a
        // usize underflow (release-mode wrap => absurd reserve)
        let err = assemble_batch(&reqs, 2, 2).unwrap_err();
        assert!(err.to_string().contains("batch of 2"), "{err}");
        // exactly-full and under-full still work
        assert!(assemble_batch(&reqs, 3, 2).is_ok());
        assert!(assemble_batch(&reqs[..1], 3, 2).is_ok());
    }

    #[test]
    fn queue_fifo_ids() {
        let mut q = Queue::new();
        let a = q.push(vec![1.0]);
        let b = q.push(vec![2.0]);
        assert_eq!((a, b), (0, 1));
        let taken = q.take(1);
        assert_eq!(taken[0].id, 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn reject_reason_codes_roundtrip() {
        for r in [RejectReason::Overloaded, RejectReason::Closing] {
            assert_eq!(RejectReason::from_code(r.code()), Some(r));
        }
        assert_eq!(RejectReason::from_code(0), None);
        assert_eq!(RejectReason::from_code(3), None);
        assert!(Rejected { reason: RejectReason::Overloaded }
            .to_string()
            .contains("overloaded"));
    }

    #[test]
    fn assemble_batch_pads_with_first_image() {
        let reqs = vec![
            Request { id: 0, image: vec![1.0, 2.0], enqueued: Instant::now() },
            Request { id: 1, image: vec![3.0, 4.0], enqueued: Instant::now() },
        ];
        let (xs, padded) = assemble_batch(&reqs, 4, 2).unwrap();
        assert_eq!(padded, 2);
        assert_eq!(xs, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 1.0, 2.0]);
    }
}
