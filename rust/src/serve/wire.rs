//! DSG serving wire protocol v1: encode/decode for the length-prefixed
//! binary messages spoken by [`super::server`].
//!
//! The normative spec is `docs/PROTOCOL.md`; this module is its
//! implementation and the golden-bytes tests at the bottom pin the two
//! together — changing the layout without updating both fails the
//! build.
//!
//! Layout summary (all integers little-endian):
//!
//! ```text
//! frame   := u32 length | payload          (length = payload bytes)
//! payload := u8 version (=1) | u8 type | body
//! ```
//!
//! Message types: `Request` (1), `Response` (2), `Reject` (3),
//! `Error` (4), `Ping` (5), `Pong` (6), `Shutdown` (7), `Flush` (8),
//! `ShutdownAck` (9, added in v1.1 — servers ack a `Shutdown` once the
//! drain completes, so clients can distinguish a graceful drain from a
//! dropped connection).  Decoding is strict: unknown version, unknown
//! type, a body of the wrong length, or a frame above [`MAX_FRAME`] are
//! errors, never best-effort guesses.

use super::RejectReason;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Protocol version byte; bump on ANY layout change.
pub const VERSION: u8 = 1;

/// Upper bound on one frame's payload (sanity guard against a
/// corrupted or hostile length prefix). 64 MiB fits a ~16M-pixel
/// request with room to spare.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// One protocol message, either direction.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Client -> server: classify one image.
    Request { id: u64, image: Vec<f32> },
    /// Server -> client: the prediction for request `id`.
    Response { id: u64, pred: u32, latency_us: u32 },
    /// Server -> client: request `id` was refused admission.
    Reject { id: u64, reason: RejectReason },
    /// Server -> client: request `id` was admitted but its batch
    /// failed (forward error or panic).
    Error { id: u64, message: String },
    /// Client -> server liveness/handshake probe.
    Ping { token: u64 },
    /// Server -> client: answer to [`Message::Ping`], same token.
    Pong { token: u64 },
    /// Client -> server: stop accepting connections and drain.
    Shutdown,
    /// Client -> server: seal the partial forming batch now instead of
    /// waiting out the batching deadline.
    Flush,
    /// Server -> client: the [`Message::Shutdown`] was honored — the
    /// server has sealed the forming batch and begun its graceful
    /// drain (in-flight replies still stream before the socket
    /// closes).
    ShutdownAck,
}

const TYPE_REQUEST: u8 = 1;
const TYPE_RESPONSE: u8 = 2;
const TYPE_REJECT: u8 = 3;
const TYPE_ERROR: u8 = 4;
const TYPE_PING: u8 = 5;
const TYPE_PONG: u8 = 6;
const TYPE_SHUTDOWN: u8 = 7;
const TYPE_FLUSH: u8 = 8;
const TYPE_SHUTDOWN_ACK: u8 = 9;

impl Message {
    /// Encode into a full frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(16);
        payload.push(VERSION);
        match self {
            Message::Request { id, image } => {
                payload.push(TYPE_REQUEST);
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&(image.len() as u32).to_le_bytes());
                for v in image {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
            }
            Message::Response { id, pred, latency_us } => {
                payload.push(TYPE_RESPONSE);
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&pred.to_le_bytes());
                payload.extend_from_slice(&latency_us.to_le_bytes());
            }
            Message::Reject { id, reason } => {
                payload.push(TYPE_REJECT);
                payload.extend_from_slice(&id.to_le_bytes());
                payload.push(reason.code());
            }
            Message::Error { id, message } => {
                payload.push(TYPE_ERROR);
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&(message.len() as u32).to_le_bytes());
                payload.extend_from_slice(message.as_bytes());
            }
            Message::Ping { token } => {
                payload.push(TYPE_PING);
                payload.extend_from_slice(&token.to_le_bytes());
            }
            Message::Pong { token } => {
                payload.push(TYPE_PONG);
                payload.extend_from_slice(&token.to_le_bytes());
            }
            Message::Shutdown => payload.push(TYPE_SHUTDOWN),
            Message::Flush => payload.push(TYPE_FLUSH),
            Message::ShutdownAck => payload.push(TYPE_SHUTDOWN_ACK),
        }
        let mut frame = Vec::with_capacity(4 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame
    }

    /// Decode one payload (frame minus its length prefix).  Strict:
    /// rejects unknown versions/types, short or oversized bodies, and
    /// trailing bytes.
    pub fn decode(payload: &[u8]) -> Result<Message> {
        ensure!(payload.len() >= 2, "payload too short: {} bytes", payload.len());
        let version = payload[0];
        ensure!(version == VERSION, "unsupported protocol version {version} (want {VERSION})");
        let ty = payload[1];
        let body = &payload[2..];
        let msg = match ty {
            TYPE_REQUEST => {
                ensure!(body.len() >= 12, "request body too short: {} bytes", body.len());
                let id = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let n = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
                ensure!(
                    body.len() == 12 + 4 * n,
                    "request body is {} bytes, expected {} for {n} pixels",
                    body.len(),
                    12 + 4 * n
                );
                let image = body[12..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Message::Request { id, image }
            }
            TYPE_RESPONSE => {
                ensure!(body.len() == 16, "response body is {} bytes, expected 16", body.len());
                Message::Response {
                    id: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                    pred: u32::from_le_bytes(body[8..12].try_into().unwrap()),
                    latency_us: u32::from_le_bytes(body[12..16].try_into().unwrap()),
                }
            }
            TYPE_REJECT => {
                ensure!(body.len() == 9, "reject body is {} bytes, expected 9", body.len());
                let id = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let reason = RejectReason::from_code(body[8])
                    .with_context(|| format!("unknown reject reason code {}", body[8]))?;
                Message::Reject { id, reason }
            }
            TYPE_ERROR => {
                ensure!(body.len() >= 12, "error body too short: {} bytes", body.len());
                let id = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let n = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
                ensure!(
                    body.len() == 12 + n,
                    "error body is {} bytes, expected {}",
                    body.len(),
                    12 + n
                );
                let message = std::str::from_utf8(&body[12..])
                    .context("error message is not UTF-8")?
                    .to_string();
                Message::Error { id, message }
            }
            TYPE_PING | TYPE_PONG => {
                ensure!(body.len() == 8, "ping/pong body is {} bytes, expected 8", body.len());
                let token = u64::from_le_bytes(body[0..8].try_into().unwrap());
                if ty == TYPE_PING {
                    Message::Ping { token }
                } else {
                    Message::Pong { token }
                }
            }
            TYPE_SHUTDOWN => {
                ensure!(body.is_empty(), "shutdown body must be empty, got {} bytes", body.len());
                Message::Shutdown
            }
            TYPE_FLUSH => {
                ensure!(body.is_empty(), "flush body must be empty, got {} bytes", body.len());
                Message::Flush
            }
            TYPE_SHUTDOWN_ACK => {
                ensure!(
                    body.is_empty(),
                    "shutdown-ack body must be empty, got {} bytes",
                    body.len()
                );
                Message::ShutdownAck
            }
            other => bail!("unknown message type {other}"),
        };
        Ok(msg)
    }
}

/// Write one message as a frame and flush.
pub fn write_frame(w: &mut impl Write, msg: &Message) -> Result<()> {
    w.write_all(&msg.encode()).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame and decode it.  Returns `Ok(None)` on clean EOF at a
/// frame boundary (the peer closed between messages); mid-frame EOF is
/// an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Message>> {
    let mut len_buf = [0u8; 4];
    match read_exact_or_eof(r, &mut len_buf)? {
        ReadOutcome::Eof => return Ok(None),
        ReadOutcome::Full => {}
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len >= 2, "frame of {len} bytes cannot hold version + type");
    ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    Message::decode(&payload).map(Some)
}

enum ReadOutcome {
    Full,
    Eof,
}

/// `read_exact` that distinguishes a clean EOF before the FIRST byte
/// from a torn read mid-buffer.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..]).context("reading frame header")?;
        if n == 0 {
            if filled == 0 {
                return Ok(ReadOutcome::Eof);
            }
            bail!("connection closed mid-frame ({filled} of {} header bytes)", buf.len());
        }
        filled += n;
    }
    Ok(ReadOutcome::Full)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let frame = m.encode();
        // length prefix is consistent
        let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        assert_eq!(frame[4], VERSION);
        let decoded = Message::decode(&frame[4..]).unwrap();
        assert_eq!(decoded, m);
        // and through the stream reader
        let mut cur = std::io::Cursor::new(frame);
        let got = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn all_message_types_roundtrip() {
        roundtrip(Message::Request { id: 7, image: vec![1.0, -2.5] });
        roundtrip(Message::Request { id: u64::MAX, image: vec![] });
        roundtrip(Message::Response { id: 3, pred: 9, latency_us: 1_250 });
        roundtrip(Message::Reject { id: 12, reason: RejectReason::Overloaded });
        roundtrip(Message::Reject { id: 12, reason: RejectReason::Closing });
        roundtrip(Message::Error { id: 4, message: "forward panicked: boom".into() });
        roundtrip(Message::Error { id: 0, message: String::new() });
        roundtrip(Message::Ping { token: 0xDEAD_BEEF });
        roundtrip(Message::Pong { token: 0xDEAD_BEEF });
        roundtrip(Message::Shutdown);
        roundtrip(Message::Flush);
        roundtrip(Message::ShutdownAck);
    }

    /// Golden bytes pin `docs/PROTOCOL.md` to the implementation: if
    /// this test needs editing, the spec (and VERSION) must change too.
    #[test]
    fn golden_request_frame() {
        let m = Message::Request { id: 7, image: vec![1.0, -2.5] };
        let frame = m.encode();
        let expect: Vec<u8> = vec![
            0x16, 0x00, 0x00, 0x00, // length = 22
            0x01, // version 1
            0x01, // type Request
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id = 7
            0x02, 0x00, 0x00, 0x00, // n = 2 pixels
            0x00, 0x00, 0x80, 0x3F, // 1.0f32
            0x00, 0x00, 0x20, 0xC0, // -2.5f32
        ];
        assert_eq!(frame, expect);
    }

    #[test]
    fn golden_response_frame() {
        let m = Message::Response { id: 258, pred: 3, latency_us: 1000 };
        let expect: Vec<u8> = vec![
            0x12, 0x00, 0x00, 0x00, // length = 18
            0x01, 0x02, // version, type Response
            0x02, 0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id = 258
            0x03, 0x00, 0x00, 0x00, // pred = 3
            0xE8, 0x03, 0x00, 0x00, // latency_us = 1000
        ];
        assert_eq!(m.encode(), expect);
    }

    #[test]
    fn golden_reject_and_control_frames() {
        let rej = Message::Reject { id: 1, reason: RejectReason::Overloaded };
        assert_eq!(
            rej.encode(),
            vec![0x0B, 0, 0, 0, 0x01, 0x03, 1, 0, 0, 0, 0, 0, 0, 0, 0x01]
        );
        assert_eq!(Message::Shutdown.encode(), vec![0x02, 0, 0, 0, 0x01, 0x07]);
        assert_eq!(Message::Flush.encode(), vec![0x02, 0, 0, 0, 0x01, 0x08]);
        assert_eq!(Message::ShutdownAck.encode(), vec![0x02, 0, 0, 0, 0x01, 0x09]);
    }

    #[test]
    fn decode_rejects_malformed() {
        // too short
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[VERSION]).is_err());
        // wrong version
        assert!(Message::decode(&[9, TYPE_FLUSH]).is_err());
        // unknown type
        assert!(Message::decode(&[VERSION, 0]).is_err());
        assert!(Message::decode(&[VERSION, 200]).is_err());
        // truncated request body
        assert!(Message::decode(&[VERSION, TYPE_REQUEST, 1, 2, 3]).is_err());
        // pixel count promises more than the body holds
        let mut p = vec![VERSION, TYPE_REQUEST];
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&5u32.to_le_bytes()); // n=5 but 0 pixel bytes
        assert!(Message::decode(&p).is_err());
        // trailing garbage after a fixed-size body
        let mut resp = Message::Response { id: 0, pred: 0, latency_us: 0 }.encode();
        resp.push(0xFF);
        // fix up the length prefix so only decode strictness can catch it
        let bad_payload = &resp[4..];
        assert!(Message::decode(bad_payload).is_err());
        // unknown reject reason
        let mut rej = vec![VERSION, TYPE_REJECT];
        rej.extend_from_slice(&0u64.to_le_bytes());
        rej.push(9);
        assert!(Message::decode(&rej).is_err());
        // shutdown with a body
        assert!(Message::decode(&[VERSION, TYPE_SHUTDOWN, 0]).is_err());
        assert!(Message::decode(&[VERSION, TYPE_SHUTDOWN_ACK, 0]).is_err());
        // error message must be UTF-8
        let mut e = vec![VERSION, TYPE_ERROR];
        e.extend_from_slice(&0u64.to_le_bytes());
        e.extend_from_slice(&2u32.to_le_bytes());
        e.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Message::decode(&e).is_err());
    }

    #[test]
    fn stream_reader_eof_semantics() {
        // clean EOF at a boundary -> None
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
        // EOF mid-header -> error
        let mut torn = std::io::Cursor::new(vec![0x02, 0x00]);
        assert!(read_frame(&mut torn).is_err());
        // EOF mid-payload -> error
        let mut mid = std::io::Cursor::new(vec![0x08, 0, 0, 0, VERSION, TYPE_FLUSH]);
        assert!(read_frame(&mut mid).is_err());
        // hostile length prefix -> error before allocating
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        let mut h = std::io::Cursor::new(huge.to_vec());
        assert!(read_frame(&mut h).is_err());
        // frame too short to hold version+type -> error
        let mut tiny = std::io::Cursor::new(vec![0x01, 0, 0, 0, VERSION]);
        assert!(read_frame(&mut tiny).is_err());
        // two frames back to back then EOF
        let mut buf = Message::Ping { token: 1 }.encode();
        buf.extend_from_slice(&Message::Flush.encode());
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), Some(Message::Ping { token: 1 }));
        assert_eq!(read_frame(&mut cur).unwrap(), Some(Message::Flush));
        assert!(read_frame(&mut cur).unwrap().is_none());
    }
}
