//! Concurrent multi-worker serving: a shared `Mutex`+`Condvar` request
//! queue feeding N worker threads, each assembling FIFO batches with a
//! deadline-based flush.
//!
//! Batch formation rules (per worker, under the queue lock):
//!
//! 1. `max_batch` requests available -> take exactly `max_batch`.
//! 2. queue closed -> take what remains (capped at `max_batch`).
//! 3. oldest request older than `max_wait` -> flush the partial batch.
//! 4. otherwise block on the condvar until a push/close, bounded by the
//!    oldest request's remaining deadline.
//!
//! Every drain takes a CONTIGUOUS chunk off the queue head.  With a
//! load that is fully enqueued before the workers start
//! ([`ConcurrentServer::serve_all`]), batch boundaries are therefore
//! `[0..B), [B..2B), ...` by construction, regardless of worker count,
//! machine speed, or scheduling — that plus the thread-count invariance
//! of `sparse::parallel` is what makes `--workers 4` produce
//! bit-identical predictions to `--workers 1`.  On the streaming
//! `start`/`submit` path a deadline flush can land mid-stream, so batch
//! composition (and with it the DSG shared-threshold masks) is
//! timing-dependent there — inherent to deadline batching, not a bug.
//!
//! The forward function runs OUTSIDE the lock; per-request latency and
//! per-batch compute go into thread-local [`LatencyHistogram`]s merged
//! at shutdown.
//!
//! Failure semantics: a batch whose forward errors or PANICS fails
//! alone — the worker contains the unwind, records one failure per
//! affected request, and keeps draining, so a poisoned request can
//! neither deadlock [`ConcurrentServer::serve_all`] nor silently starve
//! later requests.  The failure surfaces as an `Err` from
//! `shutdown`/`serve_all` after the drain completes.  With a
//! [`ServerConfig::with_queue_cap`] bound, over-capacity submits are
//! refused explicitly ([`ConcurrentServer::try_submit`] returns
//! [`Rejected`]) instead of growing the queue without limit.

use super::{argmax, assemble_batch_into, RejectReason, Rejected, Request, Response};
use crate::metrics::LatencyHistogram;
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Static serving parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads draining the shared queue.
    pub workers: usize,
    /// Full batch size (the model's fixed batch dimension).
    pub max_batch: usize,
    /// Deadline: a partial batch flushes once its oldest request has
    /// waited this long.
    pub max_wait: Duration,
    /// Flat pixels per request.
    pub input_elems: usize,
    /// Logits per sample.
    pub classes: usize,
    /// Bound on queued REQUESTS for [`ConcurrentServer::try_submit`];
    /// `0` = unbounded (never rejects).
    pub queue_cap: usize,
}

impl ServerConfig {
    pub fn new(workers: usize, max_batch: usize, input_elems: usize, classes: usize) -> Self {
        assert!(max_batch > 0 && input_elems > 0 && classes > 0);
        ServerConfig {
            workers: workers.max(1),
            max_batch,
            max_wait: Duration::from_millis(5),
            input_elems,
            classes,
            queue_cap: 0,
        }
    }

    pub fn with_max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Bound the queue at `cap` requests (`0` = unbounded).
    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }
}

struct QueueState {
    q: VecDeque<Request>,
    next_id: u64,
    closed: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    available: Condvar,
}

/// Per-worker accounting, merged into the final report.
#[derive(Default, Debug, Clone)]
pub struct WorkerStats {
    pub served: usize,
    /// Requests whose batch failed (forward error or panic).
    pub failed: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub latency: LatencyHistogram,
    pub compute: LatencyHistogram,
}

impl WorkerStats {
    fn merge(&mut self, other: &WorkerStats) {
        self.served += other.served;
        self.failed += other.failed;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.latency.merge(&other.latency);
        self.compute.merge(&other.compute);
    }
}

/// Aggregated outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// All responses, sorted by request id (FIFO order restored).
    pub responses: Vec<Response>,
    pub served: usize,
    /// Requests whose batch failed (only ever nonzero on the report a
    /// failing run would have produced; `shutdown`/`serve_all` return
    /// `Err` instead when this is nonzero).
    pub failed: usize,
    pub batches: usize,
    pub padded_slots: usize,
    /// Queue wait + compute per request.
    pub latency: LatencyHistogram,
    /// Forward duration per BATCH (one sample per batch, padding
    /// included) — not a per-request share.
    pub compute: LatencyHistogram,
    /// Wall-clock from server start to shutdown completion, seconds.
    pub wall: f64,
    pub per_worker: Vec<WorkerStats>,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        self.served as f64 / self.wall.max(1e-12)
    }

    /// Predictions in request order (the bit-exactness currency).
    pub fn predictions(&self) -> Vec<usize> {
        self.responses.iter().map(|r| r.pred).collect()
    }
}

/// The multi-worker server.  `start` spawns the pool; `submit` enqueues;
/// `shutdown` closes the queue, drains it, joins the workers, and
/// returns the merged [`ServeReport`].
pub struct ConcurrentServer {
    cfg: ServerConfig,
    shared: Arc<Shared>,
    results: Arc<Mutex<Vec<Response>>>,
    /// `(request id, why)` for every request whose batch failed.
    failures: Arc<Mutex<Vec<(u64, String)>>>,
    handles: Vec<std::thread::JoinHandle<WorkerStats>>,
    started: Instant,
}

impl ConcurrentServer {
    /// Spawn `cfg.workers` threads serving `forward` (flat padded batch
    /// -> flat logits).  `forward` must tolerate concurrent calls.
    pub fn start<F>(cfg: ServerConfig, forward: F) -> ConcurrentServer
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        Self::start_with(cfg, forward, Vec::new(), false)
    }

    /// Serve a fully pre-enqueued load and drain it to completion.
    ///
    /// Every request is queued (and the queue closed) BEFORE the first
    /// worker spawns, so batch boundaries are the contiguous FIFO
    /// chunks `[0..B), [B..2B), ...` by construction — no deadline
    /// flush can split them, regardless of machine speed.  This is the
    /// entry point for anything that asserts bit-identical predictions
    /// across worker counts (`dsg serve`, the throughput bench); the
    /// streaming `start`/`submit` path stays timing-dependent by
    /// design.
    pub fn serve_all<F>(
        cfg: ServerConfig,
        forward: F,
        images: impl IntoIterator<Item = Vec<f32>>,
    ) -> Result<ServeReport>
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        Self::start_with(cfg, forward, images.into_iter().collect(), true).join_report()
    }

    fn start_with<F>(
        cfg: ServerConfig,
        forward: F,
        initial: Vec<Vec<f32>>,
        closed: bool,
    ) -> ConcurrentServer
    where
        F: Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync + 'static,
    {
        let now = Instant::now();
        let q: VecDeque<Request> = initial
            .into_iter()
            .enumerate()
            .map(|(i, image)| Request { id: i as u64, image, enqueued: now })
            .collect();
        let next_id = q.len() as u64;
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { q, next_id, closed }),
            available: Condvar::new(),
        });
        let results = Arc::new(Mutex::new(Vec::new()));
        let failures = Arc::new(Mutex::new(Vec::new()));
        let forward = Arc::new(forward);
        let handles = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let results = results.clone();
                let failures = failures.clone();
                let forward = forward.clone();
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    worker_loop(&cfg, &shared, &results, &failures, forward.as_ref())
                })
            })
            .collect();
        // wall-clock starts at `now`: serve_all workers begin draining
        // the preloaded queue during spawn, and that work must count
        ConcurrentServer { cfg, shared, results, failures, handles, started: now }
    }

    /// Enqueue one request; returns its FIFO id.  Panics if a
    /// [`ServerConfig::with_queue_cap`] bound rejects it — callers that
    /// configure a cap must use [`ConcurrentServer::try_submit`] and
    /// answer the rejection.
    pub fn submit(&self, image: Vec<f32>) -> u64 {
        self.try_submit(image)
            .expect("submit on a bounded queue rejected; use try_submit")
    }

    /// Enqueue one request, or refuse it explicitly when the queue is
    /// at `queue_cap` — the caller MUST answer the [`Rejected`] (the
    /// wire server sends a reject frame); the request is not queued and
    /// will never produce a response.
    pub fn try_submit(&self, image: Vec<f32>) -> std::result::Result<u64, Rejected> {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err(Rejected { reason: RejectReason::Closing });
        }
        if self.cfg.queue_cap > 0 && st.q.len() >= self.cfg.queue_cap {
            return Err(Rejected { reason: RejectReason::Overloaded });
        }
        let id = st.next_id;
        st.next_id += 1;
        st.q.push_back(Request { id, image, enqueued: Instant::now() });
        drop(st);
        self.shared.available.notify_one();
        Ok(id)
    }

    /// Number of requests that reached a terminal state (response OR
    /// failure) — progress pollers must not stall on a failed batch.
    pub fn completed(&self) -> usize {
        self.results.lock().unwrap().len() + self.failures.lock().unwrap().len()
    }

    /// Close the queue, let the workers drain it, join them, and merge
    /// their accounting.  Any worker error (bad request shape, failed
    /// forward) propagates.
    pub fn shutdown(self) -> Result<ServeReport> {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.join_report()
    }

    /// Join the (already-closing) workers and merge their accounting.
    /// The drain always completes first: even when batches failed, every
    /// queued request reaches a terminal state before the error returns.
    fn join_report(self) -> Result<ServeReport> {
        self.shared.available.notify_all();
        let mut total = WorkerStats::default();
        let mut per_worker = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            // workers contain batch panics internally; a join error
            // here would be a harness bug and must not wedge the drain
            if let Ok(stats) = h.join() {
                total.merge(&stats);
                per_worker.push(stats);
            }
        }
        let failures = std::mem::take(&mut *self.failures.lock().unwrap());
        if let Some((id, why)) = failures.first() {
            anyhow::bail!(
                "concurrent serve: {} request(s) failed (first: request {id}: {why})",
                failures.len()
            );
        }
        let wall = self.started.elapsed().as_secs_f64();
        let mut responses = Arc::try_unwrap(self.results)
            .map_err(|_| anyhow::anyhow!("response sink still shared after join"))?
            .into_inner()
            .unwrap();
        responses.sort_by_key(|r| r.id);
        Ok(ServeReport {
            served: total.served,
            failed: total.failed,
            batches: total.batches,
            padded_slots: total.padded_slots,
            latency: total.latency,
            compute: total.compute,
            wall,
            per_worker,
            responses,
        })
    }

    /// The configuration this server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }
}

/// Take the next batch off the queue, honoring the flush rules.
/// Returns `None` when the queue is closed and empty (worker exits).
fn next_batch(cfg: &ServerConfig, shared: &Shared) -> Option<Vec<Request>> {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.q.len() >= cfg.max_batch {
            return Some(st.q.drain(..cfg.max_batch).collect());
        }
        if st.closed {
            if st.q.is_empty() {
                return None;
            }
            let n = st.q.len().min(cfg.max_batch);
            return Some(st.q.drain(..n).collect());
        }
        let oldest_age = st.q.front().map(|r| r.enqueued.elapsed());
        match oldest_age {
            Some(age) if age >= cfg.max_wait => {
                // deadline flush: partial batch ships now
                let n = st.q.len().min(cfg.max_batch);
                return Some(st.q.drain(..n).collect());
            }
            Some(age) => {
                let (guard, _timeout) = shared
                    .available
                    .wait_timeout(st, cfg.max_wait - age)
                    .unwrap();
                st = guard;
            }
            None => {
                st = shared.available.wait(st).unwrap();
            }
        }
    }
}

fn worker_loop<F>(
    cfg: &ServerConfig,
    shared: &Shared,
    results: &Mutex<Vec<Response>>,
    failures: &Mutex<Vec<(u64, String)>>,
    forward: &F,
) -> WorkerStats
where
    F: Fn(&[f32]) -> Result<Vec<f32>>,
{
    let mut stats = WorkerStats::default();
    // one assembly buffer per worker, reused across every batch
    let mut xs: Vec<f32> = Vec::new();
    while let Some(reqs) = next_batch(cfg, shared) {
        match run_batch(cfg, forward, &reqs, &mut xs, &mut stats) {
            Ok((logits, compute)) => {
                let mut batch_out = Vec::with_capacity(reqs.len());
                for (i, r) in reqs.into_iter().enumerate() {
                    let row = &logits[i * cfg.classes..(i + 1) * cfg.classes];
                    let latency = r.enqueued.elapsed().as_secs_f64();
                    stats.served += 1;
                    stats.latency.record(latency);
                    batch_out.push(Response { id: r.id, pred: argmax(row), latency, compute });
                }
                results.lock().unwrap().extend(batch_out);
            }
            Err(why) => {
                // the batch fails alone; the worker keeps draining so a
                // poisoned request can neither hang serve_all nor stall
                // completed() pollers
                stats.failed += reqs.len();
                let mut fs = failures.lock().unwrap();
                for r in &reqs {
                    fs.push((r.id, why.clone()));
                }
            }
        }
        stats.batches += 1;
    }
    stats
}

/// Assemble + forward one batch with the unwind contained.  Returns
/// `(logits, compute seconds)` or a failure message covering the whole
/// batch.
fn run_batch<F>(
    cfg: &ServerConfig,
    forward: &F,
    reqs: &[Request],
    xs: &mut Vec<f32>,
    stats: &mut WorkerStats,
) -> std::result::Result<(Vec<f32>, f64), String>
where
    F: Fn(&[f32]) -> Result<Vec<f32>>,
{
    let padded = assemble_batch_into(reqs, cfg.max_batch, cfg.input_elems, xs)
        .map_err(|e| format!("batch assembly failed: {e:#}"))?;
    stats.padded_slots += padded;
    let t0 = Instant::now();
    let r = std::panic::catch_unwind(AssertUnwindSafe(|| forward(&xs[..])));
    let compute = t0.elapsed().as_secs_f64();
    stats.compute.record(compute);
    match r {
        Ok(Ok(logits)) if logits.len() == cfg.max_batch * cfg.classes => Ok((logits, compute)),
        Ok(Ok(logits)) => Err(format!(
            "forward returned {} logits, expected {}",
            logits.len(),
            cfg.max_batch * cfg.classes
        )),
        Ok(Err(e)) => Err(format!("forward failed: {e:#}")),
        Err(p) => Err(super::shard::panic_message(&p)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// pred = round(first pixel), same rule as the baseline pump tests.
    fn fake_forward(batch: usize, classes: usize) -> impl Fn(&[f32]) -> Result<Vec<f32>> {
        move |xs: &[f32]| {
            let per = xs.len() / batch;
            let mut out = vec![0.0f32; batch * classes];
            for i in 0..batch {
                let c = (xs[i * per].round() as usize).min(classes - 1);
                out[i * classes + c] = 1.0;
            }
            Ok(out)
        }
    }

    #[test]
    fn empty_queue_shuts_down_cleanly() {
        let cfg = ServerConfig::new(4, 8, 4, 5);
        let srv = ConcurrentServer::start(cfg, fake_forward(8, 5));
        let report = srv.shutdown().unwrap();
        assert_eq!(report.served, 0);
        assert_eq!(report.batches, 0);
        assert!(report.responses.is_empty());
        assert!(report.latency.is_empty());
    }

    #[test]
    fn single_partial_batch_pads_and_drops_pad_rows() {
        let cfg = ServerConfig::new(2, 8, 4, 5).with_max_wait(Duration::from_secs(10));
        let srv = ConcurrentServer::start(cfg, fake_forward(8, 5));
        for i in 0..3u64 {
            assert_eq!(srv.submit(vec![i as f32; 4]), i);
        }
        let report = srv.shutdown().unwrap();
        // 3 valid rows served, 5 padding rows computed but dropped
        assert_eq!(report.served, 3);
        assert_eq!(report.batches, 1);
        assert_eq!(report.padded_slots, 5);
        assert_eq!(report.responses.len(), 3);
        for (i, r) in report.responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.pred, i);
        }
        assert_eq!(report.latency.count(), 3);
        assert_eq!(report.compute.count(), 1); // one sample per batch
    }

    #[test]
    fn deadline_flush_fires_before_max_batch() {
        // max_batch 64 will never fill; the 20ms deadline must ship the
        // 2-request batch while the queue stays OPEN.
        let cfg = ServerConfig::new(2, 64, 4, 5).with_max_wait(Duration::from_millis(20));
        let srv = ConcurrentServer::start(cfg, fake_forward(64, 5));
        srv.submit(vec![1.0; 4]);
        srv.submit(vec![2.0; 4]);
        let t0 = Instant::now();
        while srv.completed() < 2 {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "deadline flush never fired"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        // flushed before shutdown with the queue still open; exact batch
        // shape is timing-dependent (a >20ms stall between the submits
        // could split them), so assert the invariants, not batches == 1
        let report = srv.shutdown().unwrap();
        assert_eq!(report.served, 2);
        assert!(report.batches >= 1);
        assert_eq!(report.served + report.padded_slots, report.batches * 64);
        assert_eq!(report.predictions(), vec![1, 2]);
    }

    #[test]
    fn fifo_ids_preserved_across_workers() {
        let n = 97u64;
        let cfg = ServerConfig::new(4, 4, 4, 8).with_max_wait(Duration::from_millis(500));
        let srv = ConcurrentServer::start(cfg, fake_forward(4, 8));
        for i in 0..n {
            srv.submit(vec![(i % 7) as f32; 4]);
        }
        let report = srv.shutdown().unwrap();
        assert_eq!(report.served, n as usize);
        // responses come back sorted by id with the right predictions
        for (i, r) in report.responses.iter().enumerate() {
            assert_eq!(r.id, i as u64, "FIFO order broken at {i}");
            assert_eq!(r.pred, i % 7, "prediction for request {i}");
        }
        // every batch is fully padded: served + padding == batches * B
        // (exact batch count is timing-dependent on the streaming path —
        // a deadline flush may split a batch; FIFO ids/preds never vary)
        assert_eq!(report.served + report.padded_slots, report.batches * 4);
        assert!(report.batches >= 25); // ceil(97 / 4)
        assert_eq!(report.latency.count(), n);
    }

    #[test]
    fn serve_all_is_deterministic_even_with_zero_max_wait() {
        // serve_all closes the queue before workers spawn, so even a
        // pathological 0ms deadline cannot split batch boundaries.
        let imgs: Vec<Vec<f32>> = (0..21).map(|i| vec![(i % 5) as f32; 4]).collect();
        let mut reports = Vec::new();
        for workers in [1usize, 4] {
            let cfg = ServerConfig::new(workers, 8, 4, 6).with_max_wait(Duration::ZERO);
            let report =
                ConcurrentServer::serve_all(cfg, fake_forward(8, 6), imgs.clone()).unwrap();
            assert_eq!(report.served, 21);
            assert_eq!(report.batches, 3); // 8 + 8 + 5(padded 3)
            assert_eq!(report.padded_slots, 3);
            reports.push(report);
        }
        assert_eq!(reports[0].predictions(), reports[1].predictions());
        assert_eq!(reports[0].predictions()[7], 2); // 7 % 5
    }

    #[test]
    fn worker_error_propagates_at_shutdown() {
        let cfg = ServerConfig::new(2, 4, 4, 5).with_max_wait(Duration::from_millis(1));
        let srv = ConcurrentServer::start(cfg, fake_forward(4, 5));
        srv.submit(vec![0.0; 3]); // wrong input_elems
        std::thread::sleep(Duration::from_millis(30));
        assert!(srv.shutdown().is_err());
    }

    #[test]
    fn panicking_forward_does_not_deadlock_serve_all() {
        // every batch panics; serve_all must return an error promptly
        // instead of hanging on dead workers
        let imgs: Vec<Vec<f32>> = (0..20).map(|_| vec![1.0; 4]).collect();
        let cfg = ServerConfig::new(2, 4, 4, 5);
        let err = ConcurrentServer::serve_all(
            cfg,
            |_: &[f32]| -> Result<Vec<f32>> { panic!("kaboom") },
            imgs,
        )
        .unwrap_err();
        assert!(err.to_string().contains("kaboom"), "{err}");
        assert!(err.to_string().contains("20 request(s) failed"), "{err}");
    }

    #[test]
    fn poisoned_batch_fails_alone_later_requests_still_serve() {
        // queue closed pre-spawn: batches are [0..4), [4..8); pixel 5.0
        // poisons only the second batch
        let imgs: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 4]).collect();
        let cfg = ServerConfig::new(1, 4, 4, 8);
        let srv = ConcurrentServer::start_with(
            cfg,
            |xs: &[f32]| -> Result<Vec<f32>> {
                assert!(!xs.contains(&5.0), "poison batch");
                fake_forward(4, 8)(xs)
            },
            imgs,
            true,
        );
        // the worker survives the panic and finishes BOTH batches
        let t0 = Instant::now();
        while srv.completed() < 8 {
            assert!(t0.elapsed() < Duration::from_secs(5), "worker died instead of continuing");
            std::thread::sleep(Duration::from_millis(2));
        }
        let err = srv.shutdown().unwrap_err();
        assert!(err.to_string().contains("4 request(s) failed"), "{err}");
        assert!(err.to_string().contains("panicked"), "{err}");
    }

    #[test]
    fn try_submit_rejects_over_capacity_instead_of_growing() {
        // 3-request cap, worker blocked by a slow forward: the burst
        // must split into admitted + explicitly rejected, nothing lost
        let cfg = ServerConfig::new(1, 2, 4, 5)
            .with_queue_cap(3)
            .with_max_wait(Duration::from_millis(1));
        let srv = ConcurrentServer::start(cfg, move |xs: &[f32]| {
            std::thread::sleep(Duration::from_millis(25));
            fake_forward(2, 5)(xs)
        });
        let mut admitted = 0usize;
        let mut rejected = 0usize;
        for i in 0..60usize {
            match srv.try_submit(vec![(i % 3) as f32; 4]) {
                Ok(_) => admitted += 1,
                Err(r) => {
                    assert_eq!(r.reason, RejectReason::Overloaded);
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "a 60-request burst past a 3-slot cap must reject");
        let report = srv.shutdown().unwrap();
        assert_eq!(report.served, admitted, "every admitted request must be served");
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn unbounded_queue_never_rejects() {
        let cfg = ServerConfig::new(1, 4, 4, 5).with_max_wait(Duration::from_millis(1));
        let srv = ConcurrentServer::start(cfg, fake_forward(4, 5));
        for i in 0..50usize {
            srv.try_submit(vec![(i % 3) as f32; 4]).expect("cap 0 must admit everything");
        }
        let report = srv.shutdown().unwrap();
        assert_eq!(report.served, 50);
    }
}
