//! Deterministic PRNG substrate (no `rand` crate in the offline env).
//!
//! PCG32 (Melissa O'Neill's `pcg32_random_r`) — small, fast, and good
//! enough statistical quality for initialization, data synthesis, and the
//! ternary Achlioptas projection matrices (paper eq. 6).  Every consumer
//! takes an explicit seed so runs are reproducible end to end.

/// PCG32: 64-bit state, 32-bit output, XSH-RR output function.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-argument constructor (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits => exactly representable, never 1.0
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) via Lemire's method (unbiased enough for
    /// our workloads; exact rejection for small n).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box-Muller (one value per call; the pair's
    /// sibling is discarded for simplicity — init paths are not hot).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-7 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of N(0, std^2) values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Ternary Achlioptas entries (paper eq. 6):
    /// +sqrt(s) w.p. 1/(2s), -sqrt(s) w.p. 1/(2s), 0 w.p. 1 - 1/s.
    pub fn ternary_vec(&mut self, n: usize, s: u32) -> Vec<f32> {
        let val = (s as f32).sqrt();
        let p = 1.0 / (2.0 * s as f32);
        (0..n)
            .map(|_| {
                let u = self.uniform();
                if u < p {
                    -val
                } else if u < 2.0 * p {
                    val
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A fresh generator derived from this one (for splitting streams).
    pub fn split(&mut self) -> Pcg32 {
        Pcg32::new(self.next_u64(), self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg32::seeded(3);
        let xs: Vec<f32> = (0..20_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(4);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
        // all residues reachable
        let mut seen = [false; 13];
        for _ in 0..10_000 {
            seen[r.below(13) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let xs = r.normal_vec(50_000, 1.0);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn ternary_distribution() {
        let mut r = Pcg32::seeded(6);
        let xs = r.ternary_vec(60_000, 3);
        let s3 = 3f32.sqrt();
        let zero = xs.iter().filter(|&&x| x == 0.0).count() as f32 / xs.len() as f32;
        let pos = xs.iter().filter(|&&x| x == s3).count() as f32 / xs.len() as f32;
        let neg = xs.iter().filter(|&&x| x == -s3).count() as f32 / xs.len() as f32;
        assert!((zero - 2.0 / 3.0).abs() < 0.02, "P(0) = {zero}");
        assert!((pos - 1.0 / 6.0).abs() < 0.02, "P(+) = {pos}");
        assert!((neg - 1.0 / 6.0).abs() < 0.02, "P(-) = {neg}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg32::seeded(9);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
