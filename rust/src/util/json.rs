//! Minimal JSON parser/writer substrate (no `serde` in the offline env).
//!
//! Supports the full JSON grammar we emit from python (`aot.py` meta
//! files, golden indices) plus config files: objects, arrays, strings
//! with escapes, numbers, booleans, null.  Not streaming — documents here
//! are tens of KB at most.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name (meta files must be
    /// complete; a missing field is a build bug, not a runtime choice).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not an array"))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build an object from pairs (writer-side convenience).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {} }"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"batch":64,"files":{"train":"mlp.train.hlo.txt"},"eps":0.5,"ok":true,"xs":[1,2.5,"s",null]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn req_helpers() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 3);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_arr("a").unwrap().len(), 1);
        assert!(j.req("missing").is_err());
        assert!(j.req_str("n").is_err());
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn parses_real_meta_shape() {
        // A trimmed copy of what aot.py emits.
        let src = r#"{
 "name": "mlp", "batch": 64, "input_shape": [784], "classes": 10,
 "opts": {"eps": 0.5, "strategy": "drs", "double_mask": true, "use_bn": true},
 "counts": {"params": 4, "vel": 4, "bn": 4, "vbn": 4, "bn_state": 4, "wps": 2, "rs": 2, "dsg": 2},
 "state": [{"name": "params.0.w", "shape": [784, 256], "dtype": "f32", "init": {"kind": "he_normal", "fan_in": 784}}]
}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req_usize("batch").unwrap(), 64);
        let st = j.req_arr("state").unwrap();
        assert_eq!(st[0].req_str("dtype").unwrap(), "f32");
        assert_eq!(
            st[0].req("init").unwrap().req_str("kind").unwrap(),
            "he_normal"
        );
    }
}
