//! Deterministic fault injection: named sites, a reproducible schedule,
//! and zero work when disarmed.
//!
//! The recovery machinery (atomic checkpoints, retry-with-backoff,
//! accept-loop backoff, slow-client disconnects) is only trustworthy if
//! its failure paths actually run.  This module lets a test — or an
//! operator via the `DSG_FAULTS` env var — fail the Nth occurrence of a
//! named operation, exactly and reproducibly:
//!
//! ```text
//! DSG_FAULTS="ckpt.write:io@3,wire.read:io@2,ckpt.fsync:io@1+"
//!            site ───┘     │   │└ 1-based hit index; trailing `+`
//!            kind ─────────┘   │  means "that hit and every later one"
//!            (io | torn        └ comma-separated entries
//!             | stall)
//! ```
//!
//! Sites wired in this crate: `ckpt.write`, `ckpt.fsync`, `ckpt.rename`
//! (checkpoint save path), `tape.decompress` (ZVC backward walk),
//! `serve.worker_batch` (sharded batch execution), `wire.read`,
//! `wire.write` (per-connection socket I/O), `accept` (listener loop),
//! `shard.step` (data-parallel leaf step, worker side), `allreduce.send`
//! (gradient-frame encode/send, worker side), `allreduce.recv`
//! (gradient-frame receive/decode, coordinator side).
//!
//! Kinds: `io` makes the operation return an injected
//! [`std::io::Error`]; `torn` additionally asks write-shaped sites to
//! persist a PREFIX of the buffer before failing (simulating a
//! kill -9 mid-write) — gradient-frame sites truncate the frame instead,
//! so the receiver sees a non-canonical buffer; `stall` makes the
//! operation sleep `DSG_FAULT_STALL_MS` (default 50) before proceeding
//! — a straggler, not a failure.  Sites that cannot tear treat `torn`
//! as `io`; sites routed through [`check_io`] absorb a `stall` as pure
//! delay (counted in the recovery summary).
//!
//! The normative contract (see `docs/ARCHITECTURE.md`, "Failure model &
//! recovery"): **faults move time and availability, never bits.**  An
//! injected fault may kill a run, drop a connection, or force a retry —
//! but any run that completes, and any resumed run, must produce
//! bit-identical results to an unfaulted one.
//!
//! Scoping: the env schedule (and [`install`]) arms a process-global
//! plan — hit counters are shared by every thread, which is what lets a
//! schedule reach serving workers.  [`with_plan`] arms a thread-local
//! plan instead (checked first), so training-path tests can inject
//! faults without leaking into concurrently running tests.  When
//! nothing is armed, a site check is one `Once` + one relaxed atomic
//! load — effectively free.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Once, RwLock};

/// What an armed site does to the operation that hit it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation returns an injected I/O error; nothing happened.
    Io,
    /// Write-shaped sites persist a prefix of the buffer, THEN error
    /// (a crash mid-write).  Elsewhere identical to [`FaultKind::Io`].
    Torn,
    /// The operation sleeps `DSG_FAULT_STALL_MS` and then proceeds
    /// normally — a straggler.  The op itself succeeds; whether the
    /// delay is absorbed or trips a deadline is the caller's policy.
    Stall,
}

/// One schedule entry: fail `site`'s `at`-th hit (1-based); with
/// `persistent`, every hit from `at` onward.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub site: String,
    pub kind: FaultKind,
    pub at: u64,
    pub persistent: bool,
}

/// A parsed, not-yet-armed schedule.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the `DSG_FAULTS` grammar: comma-separated
    /// `site:kind@N` / `site:kind@N+` entries (see module docs).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (site, rest) = entry
                .split_once(':')
                .ok_or_else(|| format!("fault entry {entry:?}: expected site:kind@N"))?;
            let (kind, at) = rest
                .split_once('@')
                .ok_or_else(|| format!("fault entry {entry:?}: expected site:kind@N"))?;
            let kind = match kind {
                "io" => FaultKind::Io,
                "torn" => FaultKind::Torn,
                "stall" => FaultKind::Stall,
                other => return Err(format!("fault entry {entry:?}: unknown kind {other:?}")),
            };
            let (at, persistent) = match at.strip_suffix('+') {
                Some(n) => (n, true),
                None => (at, false),
            };
            let at: u64 = at
                .parse()
                .map_err(|_| format!("fault entry {entry:?}: bad hit index {at:?}"))?;
            if at == 0 {
                return Err(format!("fault entry {entry:?}: hit indices are 1-based"));
            }
            if site.is_empty() {
                return Err(format!("fault entry {entry:?}: empty site"));
            }
            specs.push(FaultSpec { site: site.to_string(), kind, at, persistent });
        }
        Ok(FaultPlan { specs })
    }

    /// Single-entry convenience for tests.
    pub fn one(site: &str, kind: FaultKind, at: u64, persistent: bool) -> FaultPlan {
        FaultPlan {
            specs: vec![FaultSpec { site: site.to_string(), kind, at, persistent }],
        }
    }
}

/// An armed plan: per-site hit counters + the specs watching each site.
struct ActivePlan {
    sites: HashMap<String, SiteState>,
}

struct SiteState {
    hits: AtomicU64,
    specs: Vec<(FaultKind, u64, bool)>,
}

impl ActivePlan {
    fn new(plan: &FaultPlan) -> ActivePlan {
        let mut sites: HashMap<String, SiteState> = HashMap::new();
        for s in &plan.specs {
            sites
                .entry(s.site.clone())
                .or_insert_with(|| SiteState { hits: AtomicU64::new(0), specs: Vec::new() })
                .specs
                .push((s.kind, s.at, s.persistent));
        }
        ActivePlan { sites }
    }

    /// Count one hit on `site`; return the injected kind if a spec
    /// matches this hit index.  Sites with no spec are not counted.
    fn hit(&self, site: &str) -> Option<FaultKind> {
        let st = self.sites.get(site)?;
        let n = st.hits.fetch_add(1, Ordering::Relaxed) + 1;
        st.specs
            .iter()
            .find(|(_, at, persistent)| n == *at || (*persistent && n >= *at))
            .map(|(kind, _, _)| *kind)
    }
}

static GLOBAL_ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();
static GLOBAL_PLAN: RwLock<Option<Arc<ActivePlan>>> = RwLock::new(None);
static ENV_PLAN: RwLock<Option<Arc<ActivePlan>>> = RwLock::new(None);
static TEST_MUTEX: Mutex<()> = Mutex::new(());

thread_local! {
    static LOCAL_PLAN: std::cell::RefCell<Option<Arc<ActivePlan>>> =
        const { std::cell::RefCell::new(None) };
}

fn ensure_env_init() {
    ENV_INIT.call_once(|| {
        let Ok(s) = std::env::var("DSG_FAULTS") else { return };
        if s.trim().is_empty() {
            return;
        }
        match FaultPlan::parse(&s) {
            Ok(plan) => {
                let active = Arc::new(ActivePlan::new(&plan));
                *ENV_PLAN.write().unwrap() = Some(active.clone());
                *GLOBAL_PLAN.write().unwrap() = Some(active);
                GLOBAL_ARMED.store(true, Ordering::Release);
                crate::warn!("DSG_FAULTS armed: {s}");
            }
            Err(e) => crate::warn!("ignoring unparseable DSG_FAULTS: {e}"),
        }
    });
}

/// Arm `plan` process-globally (replacing any env-derived plan until
/// [`clear`]), with fresh hit counters.  Reaches every thread,
/// including serving workers.  Tests using this must serialize on
/// [`test_guard`] — the plan is process-wide.
pub fn install(plan: &FaultPlan) {
    ensure_env_init();
    *GLOBAL_PLAN.write().unwrap() = Some(Arc::new(ActivePlan::new(plan)));
    GLOBAL_ARMED.store(true, Ordering::Release);
}

/// Disarm an [`install`]ed plan, restoring the `DSG_FAULTS` env plan
/// (with its hit counters intact) if one exists.
pub fn clear() {
    ensure_env_init();
    let env = ENV_PLAN.read().unwrap().clone();
    let armed = env.is_some();
    *GLOBAL_PLAN.write().unwrap() = env;
    GLOBAL_ARMED.store(armed, Ordering::Release);
}

/// Serializes tests that [`install`] a global plan.
pub fn test_guard() -> MutexGuard<'static, ()> {
    // a previous test may have panicked while holding the guard; the
    // shared state is reset by the next install/clear, so the poison
    // carries no information
    TEST_MUTEX.lock().unwrap_or_else(|p| p.into_inner())
}

/// Run `f` with `plan` armed for THIS thread only (checked before the
/// global plan; counters are fresh).  The plan is disarmed when `f`
/// returns or unwinds.
pub fn with_plan<T>(plan: &FaultPlan, f: impl FnOnce() -> T) -> T {
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            LOCAL_PLAN.with(|l| *l.borrow_mut() = None);
        }
    }
    LOCAL_PLAN.with(|l| *l.borrow_mut() = Some(Arc::new(ActivePlan::new(plan))));
    let _reset = Reset;
    f()
}

/// An opaque handle to the plan a thread currently sees (thread-local
/// first, then global), captured so worker threads spawned INSIDE a
/// [`with_plan`] scope can share it — and, critically, share its hit
/// counters — via [`scoped`].  Cheap to clone; an empty handle is a
/// no-op.
#[derive(Clone, Default)]
pub struct PlanHandle(Option<Arc<ActivePlan>>);

/// Capture the currently effective plan (with live counters) for
/// re-arming on another thread via [`scoped`].
pub fn capture() -> PlanHandle {
    let local = LOCAL_PLAN.with(|l| l.borrow().clone());
    if local.is_some() {
        return PlanHandle(local);
    }
    ensure_env_init();
    if !GLOBAL_ARMED.load(Ordering::Acquire) {
        return PlanHandle(None);
    }
    PlanHandle(GLOBAL_PLAN.read().unwrap().clone())
}

/// Run `f` with a [`capture`]d plan armed thread-locally (counters are
/// SHARED with the capturing thread, not fresh — hits on any thread
/// advance the same schedule).  Disarmed when `f` returns or unwinds;
/// an empty handle just runs `f`.
pub fn scoped<T>(handle: &PlanHandle, f: impl FnOnce() -> T) -> T {
    let Some(plan) = &handle.0 else { return f() };
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            LOCAL_PLAN.with(|l| *l.borrow_mut() = None);
        }
    }
    LOCAL_PLAN.with(|l| *l.borrow_mut() = Some(plan.clone()));
    let _reset = Reset;
    f()
}

/// Count one hit on `site` against the armed plan (thread-local first,
/// then global) and return the fault to inject, if any.  `None` means
/// proceed normally — and costs ~nothing when no plan is armed.
pub fn check(site: &str) -> Option<FaultKind> {
    let local = LOCAL_PLAN.with(|l| l.borrow().clone());
    if let Some(plan) = local {
        let hit = plan.hit(site);
        if hit.is_some() {
            crate::metrics::recovery().on_fault_injected();
        }
        return hit;
    }
    ensure_env_init();
    if !GLOBAL_ARMED.load(Ordering::Acquire) {
        return None;
    }
    let plan = GLOBAL_PLAN.read().unwrap().clone()?;
    let hit = plan.hit(site);
    if hit.is_some() {
        crate::metrics::recovery().on_fault_injected();
    }
    hit
}

/// The injected error for `site` (both kinds map to an I/O error here;
/// sites that can tear call [`check`] directly to get the kind).
pub fn injected_error(site: &str) -> std::io::Error {
    std::io::Error::other(format!("injected fault at {site}"))
}

/// Injected stall duration: `DSG_FAULT_STALL_MS`, default 50ms.
pub fn stall_ms() -> u64 {
    std::env::var("DSG_FAULT_STALL_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50)
}

/// Absorb an injected [`FaultKind::Stall`]: sleep the configured
/// duration, count it in the recovery summary, and proceed.
pub fn absorb_stall() {
    crate::metrics::recovery().on_stall_absorbed();
    std::thread::sleep(std::time::Duration::from_millis(stall_ms()));
}

/// [`check`] shaped for `?`: `Err` with an injected I/O error when the
/// schedule says this hit fails.  A `stall` is absorbed in place (sleep,
/// then `Ok`) — sites with their own deadline policy call [`check`]
/// directly instead.
pub fn check_io(site: &str) -> std::io::Result<()> {
    match check(site) {
        Some(FaultKind::Stall) => {
            absorb_stall();
            Ok(())
        }
        Some(_) => Err(injected_error(site)),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar() {
        let p = FaultPlan::parse("ckpt.write:io@3, wire.read:torn@2+ ,accept:io@1").unwrap();
        assert_eq!(p.specs.len(), 3);
        assert_eq!(
            p.specs[0],
            FaultSpec { site: "ckpt.write".into(), kind: FaultKind::Io, at: 3, persistent: false }
        );
        assert_eq!(
            p.specs[1],
            FaultSpec { site: "wire.read".into(), kind: FaultKind::Torn, at: 2, persistent: true }
        );
        assert!(FaultPlan::parse("").unwrap().specs.is_empty());
        assert!(FaultPlan::parse("bad").is_err());
        assert!(FaultPlan::parse("site:zap@1").is_err());
        assert!(FaultPlan::parse("site:io@0").is_err());
        assert!(FaultPlan::parse("site:io@x").is_err());
        assert!(FaultPlan::parse(":io@1").is_err());
    }

    #[test]
    fn exact_hit_fires_once() {
        let plan = FaultPlan::one("t.exact", FaultKind::Io, 3, false);
        with_plan(&plan, || {
            assert_eq!(check("t.exact"), None);
            assert_eq!(check("t.exact"), None);
            assert_eq!(check("t.exact"), Some(FaultKind::Io));
            assert_eq!(check("t.exact"), None);
            // other sites are never affected
            assert_eq!(check("t.other"), None);
        });
        // disarmed outside the scope
        assert_eq!(check("t.exact"), None);
    }

    #[test]
    fn persistent_hit_fires_from_n_onward() {
        let plan = FaultPlan::one("t.persist", FaultKind::Torn, 2, true);
        with_plan(&plan, || {
            assert_eq!(check("t.persist"), None);
            assert_eq!(check("t.persist"), Some(FaultKind::Torn));
            assert_eq!(check("t.persist"), Some(FaultKind::Torn));
        });
    }

    #[test]
    fn check_io_maps_to_error() {
        let plan = FaultPlan::one("t.io", FaultKind::Io, 1, false);
        with_plan(&plan, || {
            let e = check_io("t.io").unwrap_err();
            assert!(e.to_string().contains("t.io"), "{e}");
            assert!(check_io("t.io").is_ok());
        });
    }

    #[test]
    fn parse_stall_kind() {
        let p = FaultPlan::parse("shard.step:stall@2+").unwrap();
        assert_eq!(
            p.specs[0],
            FaultSpec { site: "shard.step".into(), kind: FaultKind::Stall, at: 2, persistent: true }
        );
    }

    #[test]
    fn check_io_absorbs_stall() {
        let plan = FaultPlan::one("t.stall", FaultKind::Stall, 1, false);
        with_plan(&plan, || {
            let before = std::time::Instant::now();
            assert!(check_io("t.stall").is_ok());
            assert!(before.elapsed().as_millis() >= 10, "stall did not sleep");
            assert!(check_io("t.stall").is_ok());
        });
    }

    #[test]
    fn captured_plan_shares_counters_across_threads() {
        // a worker armed via capture()/scoped() must see the SAME
        // schedule (shared hit counters), unlike a bare spawn
        let plan = FaultPlan::one("t.cap", FaultKind::Io, 2, false);
        with_plan(&plan, || {
            let h = capture();
            assert_eq!(check("t.cap"), None); // hit 1 on this thread
            let got = std::thread::scope(|s| {
                s.spawn(|| scoped(&h, || check("t.cap"))).join().unwrap()
            });
            assert_eq!(got, Some(FaultKind::Io)); // hit 2 on the worker
            assert_eq!(check("t.cap"), None); // hit 3: schedule exhausted
        });
    }

    #[test]
    fn empty_capture_is_a_noop() {
        let h = capture();
        let got = scoped(&h, || check("t.none"));
        assert_eq!(got, None);
    }

    #[test]
    fn thread_local_plan_does_not_leak_to_other_threads() {
        let plan = FaultPlan::one("t.tl", FaultKind::Io, 1, true);
        with_plan(&plan, || {
            assert_eq!(check("t.tl"), Some(FaultKind::Io));
            let h = std::thread::spawn(|| check("t.tl"));
            assert_eq!(h.join().unwrap(), None);
        });
    }

    #[test]
    fn install_reaches_other_threads_and_clear_disarms() {
        let _g = test_guard();
        install(&FaultPlan::one("t.global", FaultKind::Io, 1, true));
        let h = std::thread::spawn(|| check("t.global"));
        assert_eq!(h.join().unwrap(), Some(FaultKind::Io));
        clear();
        let h = std::thread::spawn(|| check("t.global"));
        assert_eq!(h.join().unwrap(), None);
    }
}
