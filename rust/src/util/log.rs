//! Tiny leveled logger (stderr). `DSG_LOG=debug|info|warn|error` selects
//! verbosity; default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static THRESHOLD: AtomicU8 = AtomicU8::new(255);

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != 255 {
        return t;
    }
    let lvl = match std::env::var("DSG_LOG").as_deref() {
        Ok("debug") => 0,
        Ok("warn") => 2,
        Ok("error") => 3,
        _ => 1,
    };
    THRESHOLD.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI `-v`).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, msg: &str) {
    if (level as u8) < threshold() {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:.3} {tag}] {msg}");
}

#[macro_export]
macro_rules! debug { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) } }
#[macro_export]
macro_rules! info { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) } }
#[macro_export]
macro_rules! warn { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) } }
#[macro_export]
macro_rules! error { ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, &format!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        log(Level::Debug, "should not panic, should not print");
        set_level(Level::Info);
    }
}
