//! Infrastructure substrates built in-repo (the offline environment has
//! no serde/clap/rand/criterion): JSON, PRNG, logging, timing.

pub mod faults;
pub mod json;
pub mod log;
pub mod rng;

pub use json::Json;
pub use rng::Pcg32;

/// Wall-clock seconds of a closure (used by the bench harness and the
/// coordinator's step timing).
pub fn time_secs<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Format a byte count as a human-readable string.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn time_secs_returns_value() {
        let (v, dt) = time_secs(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
