//! Crash-safe checkpointing: serialize the full model state (training
//! state + Wp + R) with an integrity header, written atomically.
//!
//! Format v2: `magic "DSGCKPT2" | u64 steps_done | u32 n_sections(=3) |
//! u32 header_crc` then per section `u64 body_len | body | u32 crc32(body)`
//! where body = `u32 n_tensors | tensors` and a tensor is
//! `u32 ndim | u64 dims[ndim] | u8 dtype (0=f32,1=s32) | payload LE bytes`.
//! `header_crc` covers the 20 bytes before it; every byte of the file is
//! under some CRC or validated structurally, so a torn or bit-flipped
//! file NEVER loads — it is skipped (see [`CheckpointDir::latest_valid`]).
//!
//! Write path: encode in memory → write to a sibling `.tmp` (name made
//! unique per process + save, so two writers can never interleave into
//! one staging file) → fsync → atomic rename → fsync the parent
//! directory.  A crash at any point leaves either the old file intact
//! or a `.tmp` that loaders ignore; it can never tear the file a resume
//! would read.  Fault-injection sites (`ckpt.write`, `ckpt.fsync`,
//! `ckpt.rename` — see [`crate::util::faults`]) let tests kill the save
//! at every stage.
//!
//! Retention (keep-last-K) and the stray-`.tmp` sweep are serialized
//! across processes sharing a `--ckpt-dir` by an exclusive
//! `.retention.lock` file (`O_EXCL` create, deleted on drop, stale
//! locks from crashed holders broken by age).  Without it two
//! concurrent savers could list the directory at different moments and
//! each prune the other's newest file; with it the sweep always sees a
//! settled listing.  The tmp sweep additionally only removes `.tmp`
//! files old enough that they cannot be another process's in-flight
//! save.
//!
//! v1 files (`DSGCKPT1`, no steps / no CRC) still load, with
//! `steps_done = 0`; the parse is hardened the same way.

use crate::coordinator::init::ModelState;
use crate::runtime::HostTensor;
use crate::util::faults;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

const MAGIC_V1: &[u8; 8] = b"DSGCKPT1";
const MAGIC_V2: &[u8; 8] = b"DSGCKPT2";

/// Write granularity: one fault-site check per chunk, so
/// `ckpt.write:io@3` fails the 3rd 64 KiB of a save.
const WRITE_CHUNK: usize = 64 * 1024;

// ---------------------------------------------------------------- crc32

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// --------------------------------------------------------------- encode

fn encode_tensor(out: &mut Vec<u8>, t: &HostTensor) {
    let shape = t.shape();
    out.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for &d in shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    match t {
        HostTensor::F32 { data, .. } => {
            out.push(0u8);
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        HostTensor::S32 { data, .. } => {
            out.push(1u8);
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}

/// Encode `(ms, steps)` to the full v2 byte image.
pub fn to_bytes(ms: &ModelState, steps: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + ms.total_elems() * 4);
    out.extend_from_slice(MAGIC_V2);
    out.extend_from_slice(&steps.to_le_bytes());
    out.extend_from_slice(&3u32.to_le_bytes());
    let hcrc = crc32(&out);
    out.extend_from_slice(&hcrc.to_le_bytes());
    for section in [&ms.state, &ms.wps, &ms.rs] {
        let mut body = Vec::new();
        body.extend_from_slice(&(section.len() as u32).to_le_bytes());
        for t in section.iter() {
            encode_tensor(&mut body, t);
        }
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        let bcrc = crc32(&body);
        out.extend_from_slice(&body);
        out.extend_from_slice(&bcrc.to_le_bytes());
    }
    out
}

// ---------------------------------------------------------------- parse
//
// Total, slice-based parse: every length is bounds-checked against the
// bytes actually present, element counts use checked arithmetic, and no
// allocation is sized from an untrusted field (payloads collect from
// the real slice).  Mirrors the `zvc::from_bytes` hardening.

struct Cur<'a> {
    rest: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.rest.len() {
            bail!("corrupt checkpoint: truncated ({} bytes left, {n} needed)", self.rest.len());
        }
        let (head, rest) = self.rest.split_at(n);
        self.rest = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn parse_tensor(c: &mut Cur) -> Result<HostTensor> {
    let ndim = c.u32()? as usize;
    if ndim > 8 {
        bail!("corrupt checkpoint: ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut elems = 1usize;
    for _ in 0..ndim {
        let d = c.u64()?;
        let d = usize::try_from(d).map_err(|_| anyhow::anyhow!("corrupt checkpoint: dim {d}"))?;
        elems = elems
            .checked_mul(d)
            .with_context(|| format!("corrupt checkpoint: element count overflow (dim {d})"))?;
        shape.push(d);
    }
    let dtype = c.u8()?;
    let nbytes = elems
        .checked_mul(4)
        .context("corrupt checkpoint: payload size overflow")?;
    // take() bounds nbytes by the bytes actually present, so the
    // collect below allocates at most the real file size.
    let raw = c.take(nbytes)?;
    Ok(match dtype {
        0 => HostTensor::F32 {
            shape,
            data: raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect(),
        },
        1 => HostTensor::S32 {
            shape,
            data: raw
                .chunks_exact(4)
                .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                .collect(),
        },
        other => bail!("corrupt checkpoint: dtype {other}"),
    })
}

fn parse_section(body: &[u8]) -> Result<Vec<HostTensor>> {
    let mut c = Cur { rest: body };
    let n = c.u32()? as usize;
    // a tensor is at least 5 bytes (ndim + dtype), so a hostile count
    // cannot force a large pre-allocation
    if n > body.len() / 5 {
        bail!("corrupt checkpoint: section of {n} tensors in {} bytes", body.len());
    }
    let mut ts = Vec::with_capacity(n);
    for _ in 0..n {
        ts.push(parse_tensor(&mut c)?);
    }
    if !c.rest.is_empty() {
        bail!("corrupt checkpoint: {} trailing bytes in section", c.rest.len());
    }
    Ok(ts)
}

/// Parse a full checkpoint image (v2 or v1).  Total: hostile or torn
/// bytes produce `Err`, never a panic or an outsized allocation.
pub fn from_bytes(bytes: &[u8]) -> Result<(ModelState, u64)> {
    let mut c = Cur { rest: bytes };
    let magic = c.take(8)?;
    let (steps, checked) = if magic == MAGIC_V2 {
        let steps = c.u64()?;
        let n_sections = c.u32()?;
        let hcrc = c.u32()?;
        if crc32(&bytes[..20]) != hcrc {
            bail!("corrupt checkpoint: header CRC mismatch");
        }
        if n_sections != 3 {
            bail!("corrupt checkpoint: {n_sections} sections");
        }
        (steps, true)
    } else if magic == MAGIC_V1 {
        (0, false)
    } else {
        bail!("not a DSG checkpoint (bad magic)");
    };
    let mut sections = Vec::with_capacity(3);
    for _ in 0..3 {
        if checked {
            let body_len = c.u64()?;
            let body_len = usize::try_from(body_len)
                .map_err(|_| anyhow::anyhow!("corrupt checkpoint: section length {body_len}"))?;
            let body = c.take(body_len)?;
            let bcrc = c.u32()?;
            if crc32(body) != bcrc {
                bail!("corrupt checkpoint: section CRC mismatch");
            }
            sections.push(parse_section(body)?);
        } else {
            // v1: no section framing; parse tensors in-stream
            let n = c.u32()? as usize;
            if n > c.rest.len() / 5 {
                bail!("corrupt checkpoint: section of {n} tensors");
            }
            let mut ts = Vec::with_capacity(n);
            for _ in 0..n {
                ts.push(parse_tensor(&mut c)?);
            }
            sections.push(ts);
        }
    }
    if !c.rest.is_empty() {
        bail!("corrupt checkpoint: {} trailing bytes", c.rest.len());
    }
    let rs = sections.pop().unwrap();
    let wps = sections.pop().unwrap();
    let state = sections.pop().unwrap();
    Ok((ModelState { state, wps, rs }, steps))
}

// ------------------------------------------------------------ save/load

fn write_chunked(f: &mut std::fs::File, bytes: &[u8]) -> std::io::Result<()> {
    for chunk in bytes.chunks(WRITE_CHUNK) {
        match faults::check("ckpt.write") {
            Some(faults::FaultKind::Torn) => {
                // a kill -9 mid-write: persist a prefix, then die
                let _ = f.write_all(&chunk[..chunk.len() / 2]);
                let _ = f.sync_all();
                return Err(faults::injected_error("ckpt.write"));
            }
            Some(faults::FaultKind::Io) => return Err(faults::injected_error("ckpt.write")),
            None => f.write_all(chunk)?,
        }
    }
    Ok(())
}

/// Monotonic per-process staging counter: with the pid it makes every
/// save's tmp name unique, so concurrent savers (threads OR processes
/// sharing a dir) never interleave writes into one staging file.
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// A fresh sibling temp path for one save (`.{name}.{pid}.{seq}.tmp`).
fn tmp_path(path: &Path) -> PathBuf {
    let name = path.file_name().map(|n| n.to_string_lossy()).unwrap_or_default();
    let pid = std::process::id();
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    path.with_file_name(format!(".{name}.{pid}.{seq}.tmp"))
}

/// Atomically save `(ms, steps)` to `path`: stage into a sibling
/// `.tmp`, fsync, rename over the target, fsync the directory.  On any
/// failure the target is untouched; a stale `.tmp` may remain (loaders
/// ignore it, [`CheckpointDir::save_step`] prunes them).
pub fn save_with_steps(path: &Path, ms: &ModelState, steps: u64) -> Result<()> {
    let bytes = to_bytes(ms, steps);
    let tmp = tmp_path(path);
    let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    write_chunked(&mut f, &bytes).with_context(|| format!("write {tmp:?}"))?;
    faults::check_io("ckpt.fsync").and_then(|()| f.sync_all()).with_context(|| format!("fsync {tmp:?}"))?;
    drop(f);
    faults::check_io("ckpt.rename")
        .and_then(|()| std::fs::rename(&tmp, path))
        .with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    #[cfg(unix)]
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            // make the rename itself durable
            std::fs::File::open(parent)
                .and_then(|d| d.sync_all())
                .with_context(|| format!("fsync dir {parent:?}"))?;
        }
    }
    crate::metrics::recovery().on_ckpt_save();
    Ok(())
}

/// Save a model state (steps recorded as 0; prefer
/// [`save_with_steps`] / [`CheckpointDir::save_step`] for resumable runs).
pub fn save(path: &Path, ms: &ModelState) -> Result<()> {
    save_with_steps(path, ms, 0)
}

/// Load a model state plus its recorded `steps_done`.
pub fn load_with_steps(path: &Path) -> Result<(ModelState, u64)> {
    let bytes = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
    from_bytes(&bytes).with_context(|| format!("parse {path:?}"))
}

/// Load a model state.
pub fn load(path: &Path) -> Result<ModelState> {
    Ok(load_with_steps(path)?.0)
}

// -------------------------------------------------------- CheckpointDir

/// A lock held longer than this is assumed to belong to a crashed
/// process and is broken.  Live holders only keep it for one directory
/// sweep — microseconds, not seconds.
const STALE_LOCK: Duration = Duration::from_secs(10);

/// A `.tmp` younger than this may be another process's in-flight save;
/// the sweep only removes older ones (crash leftovers).
const TMP_SWEEP_AGE: Duration = Duration::from_secs(60);

/// Exclusive cross-process lock on a checkpoint directory, held while
/// pruning.  Backed by `O_EXCL` creation of `.retention.lock` (works on
/// every platform without flock); deleted on drop.  Two processes
/// sharing a `--ckpt-dir` must not sweep concurrently: each would list
/// the directory at a different moment and could prune the file the
/// other just renamed into place.
struct DirLock {
    path: PathBuf,
}

impl DirLock {
    /// Try to take `dir/.retention.lock`.  Bounded retries with a short
    /// sleep; a lock older than [`STALE_LOCK`] (crashed holder) is
    /// broken and retried.  `None` means give up — callers skip the
    /// sweep rather than fail the save (the next saver prunes).
    fn acquire(dir: &Path) -> Option<DirLock> {
        let path = dir.join(".retention.lock");
        for _ in 0..50 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(_) => return Some(DirLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|md| md.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age >= STALE_LOCK)
                        .unwrap_or(false);
                    if stale {
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => return None,
            }
        }
        None
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// A directory of `step-NNNNNNNNNN.ckpt` files with keep-last-K
/// retention and torn-file-tolerant recovery.
#[derive(Debug, Clone)]
pub struct CheckpointDir {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointDir {
    /// Open (creating if needed).  Retention defaults to
    /// `DSG_CKPT_KEEP` (min 1) or 3.
    pub fn new(dir: &Path) -> Result<CheckpointDir> {
        std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
        let keep = std::env::var("DSG_CKPT_KEEP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(3)
            .max(1);
        Ok(CheckpointDir { dir: dir.to_path_buf(), keep })
    }

    pub fn with_keep(mut self, keep: usize) -> CheckpointDir {
        self.keep = keep.max(1);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("step-{step:010}.ckpt"))
    }

    /// All `step-*.ckpt` files present, newest (highest step) first.
    fn entries_desc(&self) -> Vec<(u64, PathBuf)> {
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return out };
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(step) = name
                .strip_prefix("step-")
                .and_then(|s| s.strip_suffix(".ckpt"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                out.push((step, e.path()));
            }
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out
    }

    /// Atomically save a checkpoint at `step`, then prune: keep the
    /// newest `keep` checkpoints, drop older ones and stale `.tmp`
    /// files from interrupted saves.  Pruning is serialized across
    /// savers sharing the directory by [`DirLock`]; if the lock can't
    /// be taken the sweep is skipped — retention is advisory and never
    /// worth failing a successful save over.
    pub fn save_step(&self, ms: &ModelState, step: u64) -> Result<PathBuf> {
        let path = self.path_for(step);
        save_with_steps(&path, ms, step)?;
        if let Some(_lock) = DirLock::acquire(&self.dir) {
            for (_, old) in self.entries_desc().into_iter().skip(self.keep) {
                let _ = std::fs::remove_file(old);
            }
            if let Ok(rd) = std::fs::read_dir(&self.dir) {
                for e in rd.flatten() {
                    if !e.file_name().to_string_lossy().ends_with(".tmp") {
                        continue;
                    }
                    // age gate: a fresh tmp may be another process's
                    // in-flight staging file
                    let old_enough = e
                        .metadata()
                        .and_then(|md| md.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age >= TMP_SWEEP_AGE)
                        .unwrap_or(false);
                    if old_enough {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
        Ok(path)
    }

    /// The newest checkpoint that parses and passes every CRC.  Torn or
    /// corrupt files are counted, warned about, and skipped — never an
    /// error, never a panic.  `Ok(None)` when nothing valid exists.
    pub fn latest_valid(&self) -> Result<Option<(ModelState, u64, PathBuf)>> {
        for (step, path) in self.entries_desc() {
            match load_with_steps(&path) {
                Ok((ms, steps)) => {
                    // trust the recorded steps, not the filename
                    let _ = step;
                    return Ok(Some((ms, steps, path)));
                }
                Err(e) => {
                    crate::metrics::recovery().on_ckpt_skipped();
                    crate::warn!("skipping corrupt checkpoint {path:?}: {e:#}");
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::{self, FaultKind, FaultPlan};
    use crate::util::Pcg32;

    fn tiny_state() -> ModelState {
        let mut rng = Pcg32::seeded(3);
        ModelState {
            state: vec![
                HostTensor::f32(&[2, 3], rng.normal_vec(6, 1.0)),
                HostTensor::f32(&[3], vec![0.0; 3]),
            ],
            wps: vec![HostTensor::f32(&[2, 2], rng.normal_vec(4, 1.0))],
            rs: vec![HostTensor::f32(&[2, 3], rng.ternary_vec(6, 3))],
        }
    }

    fn states_eq(a: &ModelState, b: &ModelState) -> bool {
        a.state == b.state && a.wps == b.wps && a.rs == b.rs
    }

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// All `.tmp` staging files in a directory (names are per-save
    /// unique now, so tests scan instead of predicting the path).
    fn tmp_files(dir: &Path) -> Vec<PathBuf> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                if e.file_name().to_string_lossy().ends_with(".tmp") {
                    out.push(e.path());
                }
            }
        }
        out
    }

    /// The old (pre-CRC) v1 encoding, for compat testing.
    fn encode_v1(ms: &ModelState) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        for section in [&ms.state, &ms.wps, &ms.rs] {
            out.extend_from_slice(&(section.len() as u32).to_le_bytes());
            for t in section.iter() {
                encode_tensor(&mut out, t);
            }
        }
        out
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn roundtrip_preserves_bits_and_steps() {
        let dir = tdir("dsg_ckpt_v2_rt");
        let p = dir.join("t.ckpt");
        let ms = tiny_state();
        save_with_steps(&p, &ms, 42).unwrap();
        let (ms2, steps) = load_with_steps(&p).unwrap();
        assert_eq!(steps, 42);
        assert!(states_eq(&ms, &ms2));
        // no stray tmp after a clean save
        assert!(tmp_files(&dir).is_empty());
    }

    #[test]
    fn v1_files_still_load() {
        let dir = tdir("dsg_ckpt_v1_compat");
        let p = dir.join("old.ckpt");
        let ms = tiny_state();
        std::fs::write(&p, encode_v1(&ms)).unwrap();
        let (ms2, steps) = load_with_steps(&p).unwrap();
        assert_eq!(steps, 0);
        assert!(states_eq(&ms, &ms2));
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(from_bytes(b"NOTACKPTxxxxxxxxxxxx").is_err());
        assert!(from_bytes(b"").is_err());
    }

    #[test]
    fn truncation_at_every_length_errors_never_panics() {
        let bytes = to_bytes(&tiny_state(), 7);
        for len in 0..bytes.len() {
            assert!(from_bytes(&bytes[..len]).is_err(), "prefix of {len} bytes parsed");
        }
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let bytes = to_bytes(&tiny_state(), 7);
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    from_bytes(&bad).is_err(),
                    "flip of byte {i} bit {bit} parsed successfully"
                );
            }
        }
    }

    #[test]
    fn hostile_length_fields_error_without_oom() {
        // v1 has no CRC, so corrupt length fields reach the tensor
        // parser directly — checked arithmetic + slice bounds must
        // reject them without huge allocations or panics.
        let ms = tiny_state();
        let base = encode_v1(&ms);
        // n_tensors in first section is right after the magic
        for val in [u32::MAX, 1 << 30, 100_000] {
            let mut bad = base.clone();
            bad[8..12].copy_from_slice(&val.to_le_bytes());
            assert!(from_bytes(&bad).is_err());
        }
        // ndim field of the first tensor
        for val in [u32::MAX, 9, 1 << 20] {
            let mut bad = base.clone();
            bad[12..16].copy_from_slice(&val.to_le_bytes());
            assert!(from_bytes(&bad).is_err());
        }
        // first dim of the first tensor: huge value → checked_mul /
        // bounds reject before allocating
        for val in [u64::MAX, 1 << 60, 1 << 40] {
            let mut bad = base.clone();
            bad[16..24].copy_from_slice(&val.to_le_bytes());
            assert!(from_bytes(&bad).is_err());
        }
        // random byte-level garbage after the magic
        let mut rng = Pcg32::seeded(99);
        for _ in 0..200 {
            let mut bad = base.clone();
            let i = 8 + (rng.next_u32() as usize) % (bad.len() - 8);
            bad[i] = rng.next_u32() as u8;
            let _ = from_bytes(&bad); // may be Ok (payload byte in v1) — must not panic
        }
    }

    #[test]
    fn save_is_atomic_under_injected_faults() {
        let dir = tdir("dsg_ckpt_atomic");
        let p = dir.join("t.ckpt");
        let ms = tiny_state();
        save_with_steps(&p, &ms, 1).unwrap();
        let good = std::fs::read(&p).unwrap();
        for (site, kind) in [
            ("ckpt.write", FaultKind::Io),
            ("ckpt.write", FaultKind::Torn),
            ("ckpt.fsync", FaultKind::Io),
            ("ckpt.rename", FaultKind::Io),
        ] {
            faults::with_plan(&FaultPlan::one(site, kind, 1, false), || {
                let err = save_with_steps(&p, &ms, 2);
                assert!(err.is_err(), "{site}:{kind:?} did not fail the save");
            });
            // target untouched: same bytes, still loads as step 1
            assert_eq!(std::fs::read(&p).unwrap(), good, "{site} tore the target");
            let (_, steps) = load_with_steps(&p).unwrap();
            assert_eq!(steps, 1);
        }
        // torn tmps from the failed saves never load
        for tmp in tmp_files(&dir) {
            assert!(load_with_steps(&tmp).is_err(), "{tmp:?} loaded");
        }
    }

    #[test]
    fn checkpoint_dir_retention_and_recovery() {
        let dir = tdir("dsg_ckpt_dir");
        let cd = CheckpointDir::new(&dir).unwrap().with_keep(2);
        let ms = tiny_state();
        for step in [2u64, 4, 6] {
            cd.save_step(&ms, step).unwrap();
        }
        // keep-last-2: step 2 pruned
        let steps: Vec<u64> = cd.entries_desc().iter().map(|e| e.0).collect();
        assert_eq!(steps, vec![6, 4]);
        // corrupt the newest → latest_valid falls back to step 4
        let p6 = cd.path_for(6);
        let mut bytes = std::fs::read(&p6).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p6, &bytes).unwrap();
        // and drop in a stray tmp (interrupted save) — must be ignored
        std::fs::write(dir.join(".step-0000000008.ckpt.tmp"), b"garbage").unwrap();
        let (ms2, steps, path) = cd.latest_valid().unwrap().expect("step 4 should load");
        assert_eq!(steps, 4);
        assert_eq!(path, cd.path_for(4));
        assert!(states_eq(&ms, &ms2));
        // truncate everything → None, no error
        for (_, p) in cd.entries_desc() {
            std::fs::write(&p, b"DSGCKPT2").unwrap();
        }
        assert!(cd.latest_valid().unwrap().is_none());
    }

    #[test]
    fn empty_dir_has_no_latest() {
        let dir = tdir("dsg_ckpt_empty");
        let cd = CheckpointDir::new(&dir).unwrap();
        assert!(cd.latest_valid().unwrap().is_none());
    }

    /// Two savers hammering one directory (the shared `--ckpt-dir`
    /// scenario): every save must succeed, retention must never drop
    /// the newest checkpoint, and no staging file may leak.  Before the
    /// retention lock + unique tmp names this raced: both sweeps could
    /// list the directory at different moments and prune the file the
    /// other had just renamed into place, and both staged into the same
    /// `.tmp` path.
    #[test]
    fn concurrent_savers_never_drop_the_latest() {
        let dir = tdir("dsg_ckpt_concurrent");
        let ms = tiny_state();
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let cd = CheckpointDir::new(&dir).unwrap().with_keep(2);
                let ms = &ms;
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    // interleaved step numbers: t=0 saves odd, t=1 even
                    for i in 0..20u64 {
                        let step = 1 + t + 2 * i;
                        cd.save_step(ms, step).unwrap();
                    }
                });
            }
        });
        // the single highest step written (40) must have survived every
        // concurrent sweep and still load bit-exactly
        let cd = CheckpointDir::new(&dir).unwrap().with_keep(2);
        let (ms2, steps, _) = cd.latest_valid().unwrap().expect("newest checkpoint survived");
        assert_eq!(steps, 40);
        assert!(states_eq(&ms, &ms2));
        // retention still pruned under contention (a skipped sweep or
        // two can leave a couple extra, never unbounded growth)
        assert!(cd.entries_desc().len() <= 4, "retention did not prune: {:?}", cd.entries_desc());
        // clean saves leave no staging files behind
        assert!(tmp_files(&dir).is_empty());
        // and the lock itself was released
        assert!(!dir.join(".retention.lock").exists());
    }
}
