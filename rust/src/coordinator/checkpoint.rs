//! Checkpointing: serialize the full model state (training state + Wp +
//! R) to a single binary file with an integrity header.
//!
//! Format: magic `"DSGCKPT1" | u32 n_tensors` | per tensor:
//! `u32 ndim | u64 dims[ndim] | u8 dtype (0=f32,1=s32) | payload LE bytes`.

use crate::coordinator::init::ModelState;
use crate::runtime::HostTensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"DSGCKPT1";

fn write_tensor(w: &mut impl Write, t: &HostTensor) -> Result<()> {
    let shape = t.shape();
    w.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    match t {
        HostTensor::F32 { data, .. } => {
            w.write_all(&[0u8])?;
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        HostTensor::S32 { data, .. } => {
            w.write_all(&[1u8])?;
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

fn read_exact(r: &mut impl Read, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_tensor(r: &mut impl Read) -> Result<HostTensor> {
    let ndim = u32::from_le_bytes(read_exact(r, 4)?.try_into().unwrap()) as usize;
    if ndim > 8 {
        bail!("corrupt checkpoint: ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(u64::from_le_bytes(read_exact(r, 8)?.try_into().unwrap()) as usize);
    }
    let n: usize = shape.iter().product();
    let dtype = read_exact(r, 1)?[0];
    let raw = read_exact(r, 4 * n)?;
    Ok(match dtype {
        0 => HostTensor::F32 {
            shape,
            data: raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        },
        1 => HostTensor::S32 {
            shape,
            data: raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        },
        other => bail!("corrupt checkpoint: dtype {other}"),
    })
}

/// Save a model state (with section lengths for state/wps/rs).
pub fn save(path: &Path, ms: &ModelState) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    for section in [&ms.state, &ms.wps, &ms.rs] {
        f.write_all(&(section.len() as u32).to_le_bytes())?;
        for t in section.iter() {
            write_tensor(&mut f, t)?;
        }
    }
    Ok(())
}

/// Load a model state.
pub fn load(path: &Path) -> Result<ModelState> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let magic = read_exact(&mut f, 8)?;
    if magic != MAGIC {
        bail!("{path:?} is not a DSG checkpoint");
    }
    let mut sections = Vec::with_capacity(3);
    for _ in 0..3 {
        let n = u32::from_le_bytes(read_exact(&mut f, 4)?.try_into().unwrap()) as usize;
        if n > 100_000 {
            bail!("corrupt checkpoint: section of {n} tensors");
        }
        let mut ts = Vec::with_capacity(n);
        for _ in 0..n {
            ts.push(read_tensor(&mut f)?);
        }
        sections.push(ts);
    }
    let rs = sections.pop().unwrap();
    let wps = sections.pop().unwrap();
    let state = sections.pop().unwrap();
    Ok(ModelState { state, wps, rs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn tiny_state() -> ModelState {
        let mut rng = Pcg32::seeded(3);
        ModelState {
            state: vec![
                HostTensor::f32(&[2, 3], rng.normal_vec(6, 1.0)),
                HostTensor::f32(&[3], vec![0.0; 3]),
            ],
            wps: vec![HostTensor::f32(&[2, 2], rng.normal_vec(4, 1.0))],
            rs: vec![HostTensor::f32(&[2, 3], rng.ternary_vec(6, 3))],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("dsg_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ckpt");
        let ms = tiny_state();
        save(&p, &ms).unwrap();
        let ms2 = load(&p).unwrap();
        assert_eq!(ms.state, ms2.state);
        assert_eq!(ms.wps, ms2.wps);
        assert_eq!(ms.rs, ms2.rs);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("dsg_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxxxxx").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("dsg_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.ckpt");
        save(&p, &tiny_state()).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&p).is_err());
    }
}
