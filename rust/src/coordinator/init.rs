//! State initialization from the meta init specs (rust mirror of
//! `python/compile/models.py` init; exact distributions differ by PRNG
//! but match in law: He-normal weights, zero biases/velocities, unit BN
//! scales, ternary Achlioptas projections).

use crate::runtime::{HostTensor, Init, LeafSpec, Meta};
use crate::util::Pcg32;

/// Materialize one leaf according to its init spec.
pub fn init_leaf(spec: &LeafSpec, rng: &mut Pcg32) -> HostTensor {
    let n = spec.elems();
    let data = match spec.init {
        Init::Zeros => vec![0.0; n],
        Init::Ones => vec![1.0; n],
        Init::HeNormal { fan_in } => {
            let std = (2.0 / fan_in as f32).sqrt();
            rng.normal_vec(n, std)
        }
        Init::Ternary { s } => rng.ternary_vec(n, s),
    };
    HostTensor::f32(&spec.shape, data)
}

/// Materialize a whole leaf list (state / wps / rs).
pub fn init_leaves(specs: &[LeafSpec], rng: &mut Pcg32) -> Vec<HostTensor> {
    specs.iter().map(|s| init_leaf(s, rng)).collect()
}

/// Full model state: training state + projections.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// params ++ vel ++ bn ++ vbn ++ bn_state (meta.state order)
    pub state: Vec<HostTensor>,
    /// projected weights (refreshed every `refresh_every` steps)
    pub wps: Vec<HostTensor>,
    /// fixed ternary projection matrices
    pub rs: Vec<HostTensor>,
}

impl ModelState {
    pub fn init(meta: &Meta, seed: u64) -> ModelState {
        let mut rng = Pcg32::seeded(seed);
        ModelState {
            state: init_leaves(&meta.state, &mut rng),
            wps: init_leaves(&meta.wps, &mut rng),
            rs: init_leaves(&meta.rs, &mut rng),
        }
    }

    /// Views of the five state groups.
    pub fn group<'a>(&'a self, meta: &Meta, idx: usize) -> &'a [HostTensor] {
        let r = meta.group_ranges()[idx].clone();
        &self.state[r]
    }

    pub fn params<'a>(&'a self, meta: &Meta) -> &'a [HostTensor] {
        self.group(meta, 0)
    }

    pub fn bn<'a>(&'a self, meta: &Meta) -> &'a [HostTensor] {
        self.group(meta, 2)
    }

    pub fn bn_state<'a>(&'a self, meta: &Meta) -> &'a [HostTensor] {
        self.group(meta, 4)
    }

    /// The DSG-layer weights, in dsg order (inputs to the project step).
    pub fn dsg_weights<'a>(&'a self, meta: &Meta) -> Vec<&'a HostTensor> {
        meta.dsg_weight_indices.iter().map(|&i| &self.state[i]).collect()
    }

    /// Total f32 elements held (memory accounting).
    pub fn total_elems(&self) -> usize {
        self.state.iter().map(|t| t.len()).sum::<usize>()
            + self.wps.iter().map(|t| t.len()).sum::<usize>()
            + self.rs.iter().map(|t| t.len()).sum::<usize>()
    }

    /// FNV-1a digest over every leaf's shape and exact bit pattern, in
    /// state/wps/rs order.  Two states digest equal iff they are
    /// bit-identical — what the crash-recovery CI smoke compares
    /// between an interrupted+resumed run and an uninterrupted one.
    pub fn digest(&self) -> u64 {
        fn eat(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for section in [&self.state, &self.wps, &self.rs] {
            h = eat(h, &(section.len() as u64).to_le_bytes());
            for t in section.iter() {
                h = eat(h, &(t.shape().len() as u64).to_le_bytes());
                for &d in t.shape() {
                    h = eat(h, &(d as u64).to_le_bytes());
                }
                match t {
                    HostTensor::F32 { data, .. } => {
                        for v in data {
                            h = eat(h, &v.to_bits().to_le_bytes());
                        }
                    }
                    HostTensor::S32 { data, .. } => {
                        for v in data {
                            h = eat(h, &v.to_le_bytes());
                        }
                    }
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DType;

    fn leaf(name: &str, shape: &[usize], init: Init) -> LeafSpec {
        LeafSpec { name: name.into(), shape: shape.to_vec(), dtype: DType::F32, init }
    }

    #[test]
    fn init_kinds() {
        let mut rng = Pcg32::seeded(1);
        let z = init_leaf(&leaf("z", &[4], Init::Zeros), &mut rng);
        assert_eq!(z.as_f32().unwrap(), &[0.0; 4]);
        let o = init_leaf(&leaf("o", &[3], Init::Ones), &mut rng);
        assert_eq!(o.as_f32().unwrap(), &[1.0; 3]);
        let h = init_leaf(&leaf("w", &[1000], Init::HeNormal { fan_in: 100 }), &mut rng);
        let d = h.as_f32().unwrap();
        let std = (d.iter().map(|x| x * x).sum::<f32>() / 1000.0).sqrt();
        let want = (2.0f32 / 100.0).sqrt();
        assert!((std - want).abs() / want < 0.15, "std {std} want {want}");
        let t = init_leaf(&leaf("r", &[3000], Init::Ternary { s: 3 }), &mut rng);
        let zeros = t.as_f32().unwrap().iter().filter(|&&x| x == 0.0).count();
        assert!((zeros as f32 / 3000.0 - 2.0 / 3.0).abs() < 0.05);
    }

    #[test]
    fn deterministic_by_seed() {
        let dir = crate::artifacts_dir();
        if !dir.join("mlp.meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = Meta::load(&dir, "mlp").unwrap();
        let a = ModelState::init(&meta, 7);
        let b = ModelState::init(&meta, 7);
        let c = ModelState::init(&meta, 8);
        assert_eq!(a.state[0], b.state[0]);
        assert_ne!(a.state[0], c.state[0]);
        assert_eq!(a.params(&meta).len(), meta.counts.params);
        assert_eq!(a.dsg_weights(&meta).len(), meta.counts.dsg);
    }
}
