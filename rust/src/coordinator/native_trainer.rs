//! The native training coordinator: drives paper Algorithm 1 through
//! [`crate::native::train::TrainEngine`] — no PJRT, no artifacts, no
//! python anywhere.  Shares `RunConfig`, `History`, checkpoints, and the
//! whole outer loop ([`super::trainer::run_training`]) with the
//! artifact-backed [`super::Trainer`]; the Wp refresh goes through the
//! host projection ([`crate::native::project_host`]) instead of the
//! project artifact.

use crate::config::RunConfig;
use crate::coordinator::init::ModelState;
use crate::coordinator::trainer::{run_training, run_training_opts, StepOut, TrainBackend, TrainOptions};
use crate::datasets::{BatchIter, Dataset};
use crate::metrics::{History, MemoryMeter};
use crate::drs::SelectionMode;
use crate::native::train::{TapeStorage, TrainEngine};
use crate::native::{self, Mode};
use crate::runtime::Meta;
use crate::sparse::parallel::SparseKernels;
use anyhow::Result;

/// The coordinator for one natively-trained model variant.
pub struct NativeTrainer {
    pub meta: Meta,
    pub state: ModelState,
    engine: TrainEngine,
    mode: Mode,
    // engine settings recorded so `restore` can rebuild the engine
    // configured exactly as the builders left it
    threads: usize,
    tape: TapeStorage,
    kernels: SparseKernels,
    selection: SelectionMode,
    pub steps_done: usize,
    pub history: History,
}

impl NativeTrainer {
    /// Initialize from a meta (synthesized by [`crate::native::zoo`] or
    /// loaded from an artifact dir) — weights from `ModelState::init`,
    /// initial Wp from the host projection.
    pub fn new(meta: Meta, seed: u64) -> Result<NativeTrainer> {
        let mut state = ModelState::init(&meta, seed);
        // fresh init: the wps leaves are zeros, project them from the
        // initial weights (what Trainer::new does through the artifact)
        native::project_host(&meta, &mut state)?;
        Self::with_state(meta, state)
    }

    /// Resume from an existing state (checkpoint load).  The restored
    /// Wp is TRUSTED as-is: it is amortized training state (refreshed
    /// every `refresh_every` steps, not every step), so re-projecting
    /// here would silently diverge a resumed run from the original.
    pub fn with_state(meta: Meta, state: ModelState) -> Result<NativeTrainer> {
        let threads = crate::sparse::parallel::n_threads();
        let engine = TrainEngine::new(&meta, &state)?.with_threads(threads);
        let mode = engine.default_mode();
        Ok(NativeTrainer {
            meta,
            state,
            engine,
            mode,
            threads,
            tape: TapeStorage::default(),
            kernels: SparseKernels::default(),
            selection: SelectionMode::default(),
            steps_done: 0,
            history: History::default(),
        })
    }

    /// Cap the engines' intra-op thread budget (bit-exact either way).
    pub fn with_threads(mut self, threads: usize) -> NativeTrainer {
        self.threads = threads;
        self.engine = self.engine.with_threads(threads);
        self
    }

    /// Select the training-tape storage (`--tape zvc`): ZVC-compress the
    /// taped activations, decompressing on demand in the backward.
    /// Training is bit-identical to the dense tape — ZVC is lossless.
    pub fn with_tape(mut self, tape: TapeStorage) -> NativeTrainer {
        self.tape = tape;
        self.engine = self.engine.with_tape(tape);
        self
    }

    /// Select the sparse kernel family.  Compound (default) and
    /// output-sparse-only are bit-identical — baseline/parity knobs.
    /// `simd` is the ONE relaxed mode: forward dot products carry a
    /// bounded-ULP reassociation tolerance (backward and the tape stay
    /// bit-exact); see `docs/ARCHITECTURE.md`.
    pub fn with_kernels(mut self, kernels: SparseKernels) -> NativeTrainer {
        self.kernels = kernels;
        self.engine = self.engine.with_kernels(kernels);
        self
    }

    /// Select the DRS mask-selection mode (`--selection`): unstructured
    /// shared-threshold CSR masks (default) vs structured constant
    /// fan-in in the packed `FixedK` layout.
    pub fn with_selection(mut self, selection: SelectionMode) -> NativeTrainer {
        self.selection = selection;
        self.engine = self.engine.with_selection(selection);
        self
    }

    /// Measured tape memory of the most recent training step.
    pub fn tape_memory(&self) -> &MemoryMeter {
        self.engine.memory()
    }

    /// Measured realized vs dense-equivalent multiply-adds of the most
    /// recent training step (forward + backward, per layer).
    pub fn ops(&self) -> &crate::metrics::OpsCounter {
        self.engine.ops()
    }

    /// Force dense (keep-all mask) execution — the convergence baseline.
    pub fn with_mode(mut self, mode: Mode) -> NativeTrainer {
        self.mode = mode;
        self
    }

    /// Host-side Wp refresh (the paper's every-50-iterations amortized
    /// projection).
    pub fn refresh_projection(&mut self) -> Result<()> {
        native::project_host(&self.meta, &mut self.state)
    }

    /// Run one training step on a prepared batch.
    pub fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        let out = self.engine.train_step(&mut self.state, x, y, gamma, lr, self.mode)?;
        self.steps_done += 1;
        Ok(StepOut { loss: out.loss, acc: out.acc, densities: out.densities })
    }

    /// Forward one batch in eval mode (running-stat BN); returns logits.
    pub fn forward(&mut self, x: &[f32], m: usize, gamma: f32) -> Result<Vec<f32>> {
        self.engine.forward_eval(&self.state, x, m, gamma, self.mode)
    }

    /// Evaluate accuracy over a dataset (padded final batch handled).
    pub fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32> {
        let batch = self.meta.batch;
        let c = self.meta.classes;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (xs, ys, valid) in BatchIter::eval_batches(data, batch) {
            let logits = self.forward(&xs, batch, gamma)?;
            for (i, &y) in ys.iter().enumerate().take(valid) {
                if crate::serve::argmax(&logits[i * c..(i + 1) * c]) == y as usize {
                    correct += 1;
                }
            }
            total += valid;
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// The full training loop per `cfg` (see
    /// [`super::trainer::run_training`]).  Returns final eval accuracy.
    pub fn train(&mut self, cfg: &RunConfig, train: &Dataset, test: &Dataset) -> Result<f32> {
        run_training(self, cfg, train, test)
    }

    /// [`Self::train`] with a checkpoint/resume policy (see
    /// [`super::trainer::run_training_opts`]).
    pub fn train_opts(
        &mut self,
        cfg: &RunConfig,
        train: &Dataset,
        test: &Dataset,
        opts: &TrainOptions,
    ) -> Result<f32> {
        run_training_opts(self, cfg, train, test, opts)
    }
}

impl TrainBackend for NativeTrainer {
    fn name(&self) -> &str {
        &self.meta.name
    }

    fn batch_size(&self) -> usize {
        self.meta.batch
    }

    fn refresh_projection(&mut self) -> Result<()> {
        NativeTrainer::refresh_projection(self)
    }

    fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        NativeTrainer::step(self, x, y, gamma, lr)
    }

    fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32> {
        NativeTrainer::evaluate(self, data, gamma)
    }

    fn history_mut(&mut self) -> &mut History {
        &mut self.history
    }

    fn state(&self) -> &ModelState {
        &self.state
    }

    fn steps_done(&self) -> usize {
        self.steps_done
    }

    fn restore(&mut self, state: ModelState, steps_done: usize) -> Result<()> {
        // rebuild the engine against the restored state with the
        // recorded settings; the restored Wp/R are trusted as-is
        self.engine = TrainEngine::new(&self.meta, &state)?
            .with_threads(self.threads)
            .with_tape(self.tape)
            .with_kernels(self.kernels)
            .with_selection(self.selection);
        self.state = state;
        self.steps_done = steps_done;
        Ok(())
    }
}
