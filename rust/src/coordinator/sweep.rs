//! Experiment sweep runner: orchestrates grids of training runs
//! (variant x gamma x seed), collects results, and emits CSV/JSON
//! reports — the machinery behind the Fig 5 benches and the `dsg sweep`
//! CLI subcommand.

use crate::config::{GammaSchedule, RunConfig};
use crate::coordinator::Trainer;
use crate::runtime::{Meta, Runtime};
use crate::util::json::{obj, Json};
use anyhow::Result;
use std::io::Write;

/// One grid point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub variant: String,
    pub gamma: f32,
    pub seed: u64,
}

/// One grid result.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub point: SweepPoint,
    pub eval_acc: f32,
    pub final_loss: f32,
    pub mean_density: f32,
    pub train_secs: f64,
    pub steps: usize,
}

/// Grid definition.
#[derive(Clone, Debug)]
pub struct Sweep {
    pub variants: Vec<String>,
    pub gammas: Vec<f32>,
    pub seeds: Vec<u64>,
    pub steps: usize,
}

impl Sweep {
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut out = Vec::new();
        for v in &self.variants {
            for &g in &self.gammas {
                for &s in &self.seeds {
                    out.push(SweepPoint { variant: v.clone(), gamma: g, seed: s });
                }
            }
        }
        out
    }

    /// Run the whole grid sequentially (the PJRT client is not Sync).
    pub fn run(&self, rt: &Runtime, progress: bool) -> Result<Vec<SweepResult>> {
        let dir = crate::artifacts_dir();
        let points = self.points();
        let total = points.len();
        let mut out = Vec::with_capacity(total);
        for (i, p) in points.into_iter().enumerate() {
            let meta = Meta::load(&dir, &p.variant)?;
            let mut cfg = RunConfig::preset_for_model(&p.variant);
            cfg.steps = self.steps;
            cfg.eval_every = 0;
            cfg.seed = p.seed;
            cfg.gamma = GammaSchedule::Constant(p.gamma);
            let (train, test) = crate::benchutil::data_for(&cfg);
            let mut trainer = Trainer::new(rt, meta, p.seed)?;
            let t0 = std::time::Instant::now();
            let acc = trainer.train(&cfg, &train, &test)?;
            let dens = trainer.history.mean_densities(20);
            let res = SweepResult {
                eval_acc: acc,
                final_loss: trainer.history.smoothed_loss(10).unwrap_or(f32::NAN),
                mean_density: if dens.is_empty() {
                    1.0
                } else {
                    dens.iter().sum::<f32>() / dens.len() as f32
                },
                train_secs: t0.elapsed().as_secs_f64(),
                steps: self.steps,
                point: p,
            };
            if progress {
                crate::info!(
                    "sweep {}/{}: {} gamma {} seed {} -> acc {:.3}",
                    i + 1,
                    total,
                    res.point.variant,
                    res.point.gamma,
                    res.point.seed,
                    res.eval_acc
                );
            }
            out.push(res);
        }
        Ok(out)
    }
}

/// Write sweep results as CSV.
pub fn write_csv(path: &std::path::Path, results: &[SweepResult]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "variant,gamma,seed,steps,eval_acc,final_loss,mean_density,train_secs")?;
    for r in results {
        writeln!(
            f,
            "{},{},{},{},{},{},{},{}",
            r.point.variant,
            r.point.gamma,
            r.point.seed,
            r.steps,
            r.eval_acc,
            r.final_loss,
            r.mean_density,
            r.train_secs
        )?;
    }
    Ok(())
}

/// Serialize results to JSON (for the `dsg sweep --json` report).
pub fn to_json(results: &[SweepResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                obj(vec![
                    ("variant", Json::Str(r.point.variant.clone())),
                    ("gamma", Json::Num(r.point.gamma as f64)),
                    ("seed", Json::Num(r.point.seed as f64)),
                    ("steps", Json::Num(r.steps as f64)),
                    ("eval_acc", Json::Num(r.eval_acc as f64)),
                    ("final_loss", Json::Num(r.final_loss as f64)),
                    ("mean_density", Json::Num(r.mean_density as f64)),
                    ("train_secs", Json::Num(r.train_secs)),
                ])
            })
            .collect(),
    )
}

/// Aggregate: mean eval acc per (variant, gamma) across seeds.
pub fn aggregate(results: &[SweepResult]) -> Vec<(String, f32, f32, f32)> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, String), Vec<f32>> = BTreeMap::new();
    for r in results {
        groups
            .entry((r.point.variant.clone(), format!("{:.4}", r.point.gamma)))
            .or_default()
            .push(r.eval_acc);
    }
    groups
        .into_iter()
        .map(|((v, g), accs)| {
            let mean = accs.iter().sum::<f32>() / accs.len() as f32;
            let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>()
                / accs.len() as f32;
            (v, g.parse().unwrap_or(0.0), mean, var.sqrt())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_results() -> Vec<SweepResult> {
        let mut out = Vec::new();
        for (g, a1, a2) in [(0.0f32, 0.9f32, 0.92f32), (0.8, 0.7, 0.74)] {
            for (seed, acc) in [(1u64, a1), (2, a2)] {
                out.push(SweepResult {
                    point: SweepPoint { variant: "mlp".into(), gamma: g, seed },
                    eval_acc: acc,
                    final_loss: 0.1,
                    mean_density: 1.0 - g,
                    train_secs: 1.0,
                    steps: 10,
                });
            }
        }
        out
    }

    #[test]
    fn points_cross_product() {
        let s = Sweep {
            variants: vec!["a".into(), "b".into()],
            gammas: vec![0.0, 0.5],
            seeds: vec![1, 2, 3],
            steps: 10,
        };
        assert_eq!(s.points().len(), 12);
    }

    #[test]
    fn aggregate_means() {
        let agg = aggregate(&fake_results());
        assert_eq!(agg.len(), 2);
        let (_, g0, m0, s0) = &agg[0];
        assert_eq!(*g0, 0.0);
        assert!((m0 - 0.91).abs() < 1e-6);
        assert!(*s0 > 0.0);
    }

    #[test]
    fn csv_and_json_shapes() {
        let rs = fake_results();
        let dir = std::env::temp_dir().join("dsg_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.csv");
        write_csv(&p, &rs).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert_eq!(txt.lines().count(), 5);
        let j = to_json(&rs);
        assert_eq!(j.as_arr().unwrap().len(), 4);
        assert_eq!(
            j.as_arr().unwrap()[0].req_str("variant").unwrap(),
            "mlp"
        );
    }
}
