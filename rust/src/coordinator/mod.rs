//! L3 coordinator: state init, the training orchestrator, checkpoints.
//!
//! See docs/ARCHITECTURE.md — the coordinator owns everything dynamic: batching,
//! sparsity (gamma) and LR schedules, the every-50-steps projected-weight
//! refresh (paper §3.1), evaluation, metrics, and persistence.  The HLO
//! artifacts it drives are pure functions.

pub mod checkpoint;
pub mod init;
pub mod native_trainer;
pub mod sweep;
pub mod trainer;

pub use checkpoint::CheckpointDir;
pub use init::ModelState;
pub use native_trainer::NativeTrainer;
pub use trainer::{run_training, run_training_opts, StepOut, TrainBackend, TrainOptions, Trainer};
