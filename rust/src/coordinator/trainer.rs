//! The training orchestrator: the L3 loop that owns all mutable state
//! and drives the AOT train-step artifact (paper Algorithm 1).
//!
//! Responsibilities (everything the python side deliberately does NOT
//! own): batching, gamma/lr schedules, the every-50-steps projected-
//! weight refresh, evaluation, metrics, checkpoints.
//!
//! Builds without the `xla` feature link against the stub
//! `runtime::Runtime`, so this module always compiles but every
//! constructor path fails cleanly at `Runtime::cpu()` — native-engine
//! serving (`dsg serve`) does not come through here.

use crate::config::RunConfig;
use crate::coordinator::checkpoint::CheckpointDir;
use crate::coordinator::init::ModelState;
use crate::datasets::{BatchIter, Dataset};
use crate::metrics::{History, StepRecord};
use crate::runtime::{Executable, HostTensor, Meta, Runtime};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Duration;

/// One step's scalar results.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub loss: f32,
    pub acc: f32,
    pub densities: Vec<f32>,
}

/// What a training backend must provide for [`run_training`] to drive
/// it: the artifact-backed [`Trainer`] and the artifact-free
/// [`crate::coordinator::NativeTrainer`] share the whole outer loop
/// (batching, gamma/LR schedules, the every-`refresh_every` Wp refresh,
/// eval cadence, history) through this trait.
pub trait TrainBackend {
    fn name(&self) -> &str;
    fn batch_size(&self) -> usize;
    /// Recompute Wp = f(W, R) (no-op for variants without projections).
    fn refresh_projection(&mut self) -> Result<()>;
    fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut>;
    fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32>;
    fn history_mut(&mut self) -> &mut History;
    /// The full model state (for checkpointing).
    fn state(&self) -> &ModelState;
    /// Steps completed so far.
    fn steps_done(&self) -> usize;
    /// Adopt a checkpointed state as if `steps_done` steps had run.
    /// The restored Wp is trusted as-is (amortized training state);
    /// re-projecting here would diverge a resumed run.
    fn restore(&mut self, state: ModelState, steps_done: usize) -> Result<()>;
}

/// Checkpointing/resume policy for [`run_training_opts`].
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Where periodic checkpoints go (`None` = no checkpointing).
    pub ckpt_dir: Option<CheckpointDir>,
    /// Save every N steps (0 = only the final checkpoint).
    pub ckpt_every: usize,
    /// Resume from `ckpt_dir`'s newest valid checkpoint if one exists.
    pub resume: bool,
    /// Failed saves are retried this many times with doubling backoff
    /// before the error aborts the run.
    pub save_retries: usize,
    /// Initial retry backoff (doubles per retry).
    pub retry_backoff: Duration,
}

impl Default for TrainOptions {
    fn default() -> TrainOptions {
        TrainOptions {
            ckpt_dir: None,
            ckpt_every: 0,
            resume: false,
            save_retries: 2,
            retry_backoff: Duration::from_millis(50),
        }
    }
}

impl TrainOptions {
    /// Checkpoint to `dir` every `every` steps with default retry
    /// policy (2 retries, 50 ms initial backoff).
    pub fn checkpointed(dir: CheckpointDir, every: usize) -> TrainOptions {
        TrainOptions { ckpt_dir: Some(dir), ckpt_every: every, ..TrainOptions::default() }
    }

    pub fn with_resume(mut self, resume: bool) -> TrainOptions {
        self.resume = resume;
        self
    }

    pub fn with_save_retries(mut self, retries: usize) -> TrainOptions {
        self.save_retries = retries;
        self
    }
}

/// [`CheckpointDir::save_step`] with bounded retry-with-backoff:
/// transient I/O errors (a flaky disk, an injected `ckpt.*` fault) are
/// absorbed up to `retries` times; exhaustion returns the error — the
/// run dies and recovery is resume-from-last-checkpoint.
fn save_with_retry(
    dir: &CheckpointDir,
    ms: &ModelState,
    step: u64,
    retries: usize,
    backoff: Duration,
) -> Result<PathBuf> {
    let mut delay = backoff;
    let mut attempt = 0usize;
    loop {
        match dir.save_step(ms, step) {
            Ok(p) => return Ok(p),
            Err(e) if attempt < retries => {
                attempt += 1;
                crate::metrics::recovery().on_ckpt_retry();
                crate::warn!(
                    "checkpoint save at step {step} failed (attempt {attempt}/{retries}): {e:#}; retrying in {delay:?}"
                );
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("checkpoint save at step {step} failed after {retries} retries")
                })
            }
        }
    }
}

/// The full training loop per `cfg`, shared by every backend: schedules
/// gamma and LR (`lr_decay_every == 0` means never decay — the modulo is
/// guarded, it used to divide by zero), refreshes the projection every
/// `refresh_every` steps, records history, and runs the eval cadence.
/// Returns the final eval accuracy.
pub fn run_training(
    backend: &mut impl TrainBackend,
    cfg: &RunConfig,
    train: &Dataset,
    test: &Dataset,
) -> Result<f32> {
    run_training_opts(backend, cfg, train, test, &TrainOptions::default())
}

/// [`run_training`] with a checkpoint/resume policy.  Determinism
/// contract: a run resumed from a step-`k` checkpoint replays the
/// batch stream and LR schedule up to `k` (both are pure functions of
/// `cfg` and the step index), then continues with the restored state —
/// so its final weights/BN stats are bit-identical to an uninterrupted
/// run.  Asserted for every injectable fault site in
/// `tests/native_train.rs::kill_at_every_fault_site_resume_parity`.
pub fn run_training_opts(
    backend: &mut impl TrainBackend,
    cfg: &RunConfig,
    train: &Dataset,
    test: &Dataset,
    opts: &TrainOptions,
) -> Result<f32> {
    cfg.validate()?;
    let mut start = 0usize;
    if opts.resume {
        if let Some(dir) = &opts.ckpt_dir {
            if let Some((ms, steps, path)) = dir.latest_valid()? {
                let steps = steps as usize;
                if steps > cfg.steps {
                    bail!("checkpoint {path:?} is at step {steps}, past cfg.steps {}", cfg.steps);
                }
                backend.restore(ms, steps)?;
                start = steps;
                crate::metrics::recovery().on_ckpt_resume();
                crate::info!("resumed {} from {path:?} at step {steps}", backend.name());
            }
        }
    }
    let batch = backend.batch_size();
    let mut iter = BatchIter::new(train, batch, cfg.seed ^ 0x5eed);
    let mut lr = cfg.lr;
    // deterministic fast-forward: replay the LR decays of the completed
    // steps (the checkpoint holds their result) and skip their batches
    // without materializing them — O(steps) index walking instead of
    // O(steps * batch) row gathers (bit-identical; asserted by
    // `datasets::tests::skip_batches_matches_drawn_stream` and the
    // resume-parity tests)
    for step in 1..start {
        if cfg.lr_decay_every > 0 && step % cfg.lr_decay_every == 0 {
            lr *= cfg.lr_decay;
        }
    }
    iter.skip_batches(start);
    for step in start..cfg.steps {
        if step > 0 && step % cfg.refresh_every == 0 {
            backend.refresh_projection()?;
        }
        if cfg.lr_decay_every > 0 && step > 0 && step % cfg.lr_decay_every == 0 {
            lr *= cfg.lr_decay;
        }
        let gamma = cfg.gamma.at(step);
        let (xs, ys) = iter.next_batch();
        let t0 = std::time::Instant::now();
        let out = backend.step(&xs, &ys, gamma, lr)?;
        let secs = t0.elapsed().as_secs_f64();
        backend.history_mut().push(StepRecord {
            step,
            loss: out.loss,
            acc: out.acc,
            densities: out.densities,
            secs,
        });
        if !out.loss.is_finite() {
            bail!("loss diverged (NaN/inf) at step {step}");
        }
        if let Some(dir) = &opts.ckpt_dir {
            let due = (opts.ckpt_every > 0 && (step + 1) % opts.ckpt_every == 0)
                || step + 1 == cfg.steps;
            if due {
                debug_assert_eq!(backend.steps_done(), step + 1);
                save_with_retry(
                    dir,
                    backend.state(),
                    (step + 1) as u64,
                    opts.save_retries,
                    opts.retry_backoff,
                )?;
            }
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let acc = backend.evaluate(test, cfg.gamma.target())?;
            backend.history_mut().push_eval(step + 1, acc);
            crate::info!(
                "{} step {}/{} loss {:.4} train-acc {:.3} eval-acc {:.3}",
                backend.name(),
                step + 1,
                cfg.steps,
                out.loss,
                out.acc,
                acc
            );
        }
    }
    let final_acc = backend.evaluate(test, cfg.gamma.target())?;
    backend.history_mut().push_eval(cfg.steps, final_acc);
    Ok(final_acc)
}

/// The coordinator for one model variant.
pub struct Trainer {
    pub meta: Meta,
    pub state: ModelState,
    train_exe: Rc<Executable>,
    fwd_exe: Rc<Executable>,
    project_exe: Option<Rc<Executable>>,
    pub steps_done: usize,
    pub history: History,
}

impl Trainer {
    pub fn new(rt: &Runtime, meta: Meta, seed: u64) -> Result<Trainer> {
        let train_exe = rt.load_artifact(&meta, "train")?;
        let fwd_exe = rt.load_artifact(&meta, "forward")?;
        let project_exe = if meta.has_file("project") {
            Some(rt.load_artifact(&meta, "project")?)
        } else {
            None
        };
        let state = ModelState::init(&meta, seed);
        let mut t = Trainer {
            meta,
            state,
            train_exe,
            fwd_exe,
            project_exe,
            steps_done: 0,
            history: History::default(),
        };
        t.refresh_projection()?; // initial Wp from the initial weights
        Ok(t)
    }

    /// Recompute the projected weights Wp = f(W, R) — the operation the
    /// paper amortizes to every 50 iterations.
    pub fn refresh_projection(&mut self) -> Result<()> {
        let Some(exe) = &self.project_exe else {
            return Ok(()); // dense/oracle/random variants have no Wp
        };
        let mut inputs: Vec<HostTensor> = Vec::new();
        for w in self.state.dsg_weights(&self.meta) {
            inputs.push(w.clone());
        }
        inputs.extend(self.state.rs.iter().cloned());
        let inputs = self.meta.filter_kept("project", inputs);
        let outs = exe.run(&inputs).context("project step")?;
        if outs.len() != self.meta.counts.wps {
            bail!("project returned {} outputs, expected {}", outs.len(), self.meta.counts.wps);
        }
        self.state.wps = outs;
        Ok(())
    }

    /// Run one training step on a prepared batch.
    pub fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        let m = &self.meta;
        let mut shape = vec![m.batch];
        shape.extend_from_slice(&m.input_shape);
        if x.len() != m.batch * m.input_elems() {
            bail!("x has {} elems, expected {}", x.len(), m.batch * m.input_elems());
        }
        let n_state = self.state.state.len();
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(n_state + self.state.wps.len() + self.state.rs.len() + 5);
        inputs.extend(self.state.state.iter().cloned());
        inputs.extend(self.state.wps.iter().cloned());
        inputs.extend(self.state.rs.iter().cloned());
        inputs.push(HostTensor::f32(&shape, x.to_vec()));
        inputs.push(HostTensor::s32(&[m.batch], y.to_vec()));
        inputs.push(HostTensor::scalar_f32(gamma));
        inputs.push(HostTensor::scalar_f32(lr));
        inputs.push(HostTensor::scalar_s32(self.steps_done as i32));
        let inputs = m.filter_kept("train", inputs);
        let outs = self.train_exe.run(&inputs).context("train step")?;
        let expect = n_state + 2 + m.counts.dsg;
        if outs.len() != expect {
            bail!("train step returned {} outputs, expected {expect}", outs.len());
        }
        let mut it = outs.into_iter();
        let new_state: Vec<HostTensor> = (&mut it).take(n_state).collect();
        let loss = it.next().unwrap().scalar()?;
        let acc = it.next().unwrap().scalar()?;
        let densities: Vec<f32> =
            it.map(|t| t.scalar()).collect::<Result<_>>()?;
        self.state.state = new_state;
        self.steps_done += 1;
        Ok(StepOut { loss, acc, densities })
    }

    /// Forward pass on one batch; returns logits (batch, classes).
    pub fn forward(&self, x: &[f32], gamma: f32) -> Result<Vec<f32>> {
        let m = &self.meta;
        let mut shape = vec![m.batch];
        shape.extend_from_slice(&m.input_shape);
        let mut inputs: Vec<HostTensor> = Vec::new();
        inputs.extend(self.state.params(m).iter().cloned());
        inputs.extend(self.state.bn(m).iter().cloned());
        inputs.extend(self.state.bn_state(m).iter().cloned());
        inputs.extend(self.state.wps.iter().cloned());
        inputs.extend(self.state.rs.iter().cloned());
        inputs.push(HostTensor::f32(&shape, x.to_vec()));
        inputs.push(HostTensor::scalar_f32(gamma));
        let inputs = m.filter_kept("forward", inputs);
        let outs = self.fwd_exe.run(&inputs).context("forward")?;
        Ok(outs[0].as_f32()?.to_vec())
    }

    /// Evaluate accuracy over a dataset (padded final batch handled).
    pub fn evaluate(&self, data: &Dataset, gamma: f32) -> Result<f32> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (xs, ys, valid) in BatchIter::eval_batches(data, self.meta.batch) {
            let logits = self.forward(&xs, gamma)?;
            let c = self.meta.classes;
            for (i, &y) in ys.iter().enumerate().take(valid) {
                let row = &logits[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                if pred == y as usize {
                    correct += 1;
                }
            }
            total += valid;
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// The full training loop per `cfg` (see [`run_training`]).  Returns
    /// the final eval accuracy.
    pub fn train(&mut self, cfg: &RunConfig, train: &Dataset, test: &Dataset) -> Result<f32> {
        run_training(self, cfg, train, test)
    }

    /// [`Self::train`] with a checkpoint/resume policy.
    pub fn train_opts(
        &mut self,
        cfg: &RunConfig,
        train: &Dataset,
        test: &Dataset,
        opts: &TrainOptions,
    ) -> Result<f32> {
        run_training_opts(self, cfg, train, test, opts)
    }
}

impl TrainBackend for Trainer {
    fn name(&self) -> &str {
        &self.meta.name
    }

    fn batch_size(&self) -> usize {
        self.meta.batch
    }

    fn refresh_projection(&mut self) -> Result<()> {
        Trainer::refresh_projection(self)
    }

    fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        Trainer::step(self, x, y, gamma, lr)
    }

    fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32> {
        Trainer::evaluate(self, data, gamma)
    }

    fn history_mut(&mut self) -> &mut History {
        &mut self.history
    }

    fn state(&self) -> &ModelState {
        &self.state
    }

    fn steps_done(&self) -> usize {
        self.steps_done
    }

    fn restore(&mut self, state: ModelState, steps_done: usize) -> Result<()> {
        self.state = state;
        self.steps_done = steps_done;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Trainer integration tests live in rust/tests/coordinator_integration.rs
    // (they need compiled artifacts + the PJRT client).
}
