//! `dsg` — the DSG launcher.
//!
//! Subcommands:
//!   train     train a model variant with the DSG coordinator
//!   eval      evaluate a checkpoint
//!   info      inspect artifacts / variants / cost model
//!   memory    representational-cost report (Fig 6)
//!   compute   computational-cost report (Fig 7 / Table 1)
//!   speed     CPU sparse-engine layer timings (Fig 8a)
//!   serve     concurrent batched-inference load test (native engine)
//!
//! Flags use `--key value` (or `--key=value`); run `dsg help` for usage.

use anyhow::{bail, Context, Result};
use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::Trainer;
use dsg::metrics::fmt_secs;
use dsg::runtime::{Meta, Runtime};
use dsg::coordinator::{CheckpointDir, TrainOptions};
use dsg::serve::server::{connect_retry, drive_load_with, ClientOptions, Endpoint, WireServer};
use dsg::serve::{ConcurrentServer, ServerConfig, ServerTuning, ShardedConfig, ShardedServer, SynthModel};
use dsg::{costmodel, datasets, memmodel, native, sparse};

/// Tiny argument parser: subcommand + `--key value` flags.
struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?} (flags are --key value)");
            };
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
            i += 1;
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_f32(&self, key: &str) -> Result<Option<f32>> {
        self.get(key)
            .map(|v| v.parse::<f32>().with_context(|| format!("--{key} {v:?}")))
            .transpose()
    }

    fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} {v:?}")))
            .transpose()
    }
}

fn usage() {
    println!(
        "dsg — Dynamic Sparse Graph (ICLR'19) coordinator

USAGE: dsg <command> [--flags]

COMMANDS:
  train    --model NAME [--engine artifact|native] [--gamma G] [--steps N]
           [--lr F] [--warmup N] [--refresh N] [--seed N] [--batch N]
           [--threads N] [--tape dense|zvc] [--kernels compound|output|simd]
           [--selection unstructured|structured[:blocked]] [--shards N]
           [--config FILE] [--csv FILE] [--checkpoint FILE]
           [--ckpt-dir DIR] [--ckpt-every N] [--keep K] [--resume auto]
           [--ckpt-retries N]
           `--engine native` (models: mlp, lenet, vgg8, vgg8s, resnet8,
           wrn8_2, each also as NAME_dense) trains entirely on the
           host-side engine: no PJRT, no artifacts — Algorithm 1 with
           DSG masks applied to activations AND gradients.
           `--tape zvc` stores the training tape ZVC-compressed
           (bit-identical results, Fig 6 memory saving — measured peak
           tape bytes are reported after the run).
           `--kernels output` runs the output-sparse-only kernel
           baseline (bit-identical to the default compound kernels;
           for A/B perf and ops comparisons).  `--kernels simd` runs
           the runtime-detected SIMD kernels (AVX2+FMA when the CPU
           has them, scalar otherwise) — the one mode whose forward
           dots are ULP-relaxed rather than bit-exact; DSG_SIMD=off
           forces the scalar table.
           `--selection structured` selects a constant fan-in top-k
           per row (packed FixedK masks + packed-gather kernels)
           instead of the paper's shared-threshold CSR masks;
           `structured:blocked` rounds k up to the 4-lane block.
           `--ckpt-dir DIR` writes crash-safe checkpoints (atomic
           tmp+fsync+rename, per-section CRC32) every --ckpt-every
           steps (default 50), keeping the last --keep (default 3, or
           DSG_CKPT_KEEP).  `--resume auto` restarts from the newest
           VALID checkpoint and replays deterministically: the resumed
           run's final weights are bit-identical to an uninterrupted
           one.  --ckpt-retries bounds save retry-with-backoff.
           `--shards N` trains data-parallel (native engine only):
           each batch splits into 8 pinned micro-leaves fanned over N
           sharded workers and reduced through a fixed-association
           tree, so the digest is bit-identical for ANY N (1..8) and
           through straggler retries, lost-shard re-sharding, and
           crash resume.  ZVC-compressed gradient frames; per-shard
           step/retry counts reported.  DSG_SHARD_STEP_MS bounds a
           stalled shard's round (default 30000), DSG_SHARD_RETRIES
           its blamed rounds per step before it is declared lost
           (default 2), DSG_FAULT_STALL_MS the injected stall length.
  eval     --model NAME --checkpoint FILE [--gamma G]
  info     [--model NAME]         artifact inventory / variant detail
  memory   [--gamma G]            Fig 6 representational-cost report
  compute  [--gamma G] [--eps E]  Fig 7 / Table 1 computational report
  speed    [--gamma G] [--reps N] Fig 8a sparse-engine timings
  sweep    --models a,b --gammas 0,0.5,0.9 [--seeds 1,2] [--steps N]
           [--csv FILE] [--json FILE]   grid of training runs
  serve    [--model synthetic|NAME] [--requests N] [--workers N]
           [--max-batch N] [--max-wait-ms F] [--gamma G] [--seed N]
           [--selection unstructured|structured[:blocked]]
           [--kernels compound|simd] [--checkpoint FILE]
           concurrent serving load test on the native engine: N worker
           threads drain a shared request queue through the parallel
           sparse engines; reports p50/p95/p99 latency and throughput.
           `synthetic` (default) needs no artifacts.
           [--shards N] run the sharded engine instead (per-shard block
           queues, work stealing, density shaping; add --queue-cap N
           for admission control, --no-shaping to disable shaping).
           [--listen ADDR] serve the wire protocol (docs/PROTOCOL.md)
           on a TCP `host:port` or `unix:/path` socket until a client
           sends Shutdown; --idle-ms / --write-queue override the
           connection deadlines (DSG_CONN_IDLE_MS, DSG_WRITE_QUEUE).
           [--connect ADDR] drive a listening server as a load
           generator; --verify recomputes in-process and asserts
           bit-identical predictions (synthetic model only);
           --retries N re-sends Overloaded rejects with jittered
           backoff; --shutdown stops the server afterwards.
  help

Artifacts are read from ./artifacts (override with DSG_ARTIFACTS).
Run `make artifacts` first.  DSG_THREADS caps the engine thread pool."
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("mlp").to_string();
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::preset_for_model(&model),
    };
    cfg.model = model;
    if let Some(g) = args.get_f32("gamma")? {
        cfg.gamma = match args.get_usize("warmup")? {
            Some(w) => GammaSchedule::Warmup { target: g, warmup: w },
            None => GammaSchedule::Constant(g),
        };
    }
    if let Some(v) = args.get_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.get_f32("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = args.get_usize("refresh")? {
        cfg.refresh_every = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    cfg.validate()?;

    // crash-safe checkpointing policy (atomic CRC'd files + optional
    // auto-resume); all knobs hang off --ckpt-dir
    let opts = match args.get("ckpt-dir") {
        Some(dir) => {
            let every = args.get_usize("ckpt-every")?.unwrap_or(50);
            let mut cd = CheckpointDir::new(std::path::Path::new(dir))?;
            if let Some(k) = args.get_usize("keep")? {
                cd = cd.with_keep(k);
            }
            let mut o = TrainOptions::checkpointed(cd, every);
            match args.get("resume") {
                None => {}
                Some("auto") | Some("true") => o = o.with_resume(true),
                Some(other) => bail!("unknown --resume {other:?} (auto)"),
            }
            if let Some(r) = args.get_usize("ckpt-retries")? {
                o = o.with_save_retries(r);
            }
            o
        }
        None => {
            for flag in ["ckpt-every", "keep", "resume", "ckpt-retries"] {
                anyhow::ensure!(args.get(flag).is_none(), "--{flag} requires --ckpt-dir");
            }
            TrainOptions::default()
        }
    };

    let engine = args.get("engine").unwrap_or("artifact");
    let meta = match engine {
        "native" => {
            // synthesized host-side meta: no artifacts dir needed at all
            let mut spec = native::zoo::spec_for(&cfg.model)?;
            if let Some(b) = args.get_usize("batch")? {
                anyhow::ensure!(b > 0, "--batch must be at least 1");
                spec.batch = b;
            }
            native::zoo::synth_meta(&spec)?
        }
        "artifact" => {
            // these knobs only exist natively; the artifact batch shape
            // is baked into the HLO — ignoring them would silently run
            // something other than what was asked for
            for flag in ["batch", "threads", "tape", "kernels", "selection", "shards"] {
                anyhow::ensure!(
                    args.get(flag).is_none(),
                    "--{flag} requires --engine native (the artifact batch/threading \
                     is fixed at AOT-lowering time)"
                );
            }
            Meta::load(&dsg::artifacts_dir(), &cfg.model)?
        }
        other => bail!("unknown --engine {other:?} (artifact | native)"),
    };
    println!(
        "training {} [{engine} engine] ({} params, batch {}, strategy {}) on {} for {} steps, gamma {:?}",
        meta.name,
        meta.param_elems(),
        meta.batch,
        meta.strategy,
        cfg.dataset,
        cfg.steps,
        cfg.gamma
    );
    let full = if cfg.dataset == "fashion" {
        datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed)
    } else {
        datasets::cifar_like(cfg.train_size + cfg.test_size, cfg.seed)
    };
    let (train, test) = full.split(cfg.test_size as f64 / (cfg.train_size + cfg.test_size) as f64);

    let (acc, history, state) = if engine == "native" && args.get("shards").is_some() {
        // data-parallel path: pinned micro-leaf split + fixed-tree
        // all-reduce; bit-identical digest for any shard count
        let shards = args.get_usize("shards")?.unwrap_or(1).max(1);
        let mut trainer = dsg::train::ParallelTrainer::new(meta, cfg.seed, shards)?;
        if let Some(t) = args.get_usize("threads")? {
            trainer = trainer.with_threads(t.max(1))?;
        }
        if let Some(t) = args.get("tape") {
            let tape = native::train::TapeStorage::parse(t)
                .ok_or_else(|| anyhow::anyhow!("unknown --tape {t:?} (dense | zvc)"))?;
            trainer = trainer.with_tape(tape);
        }
        if let Some(k) = args.get("kernels") {
            let kernels = sparse::parallel::SparseKernels::parse(k)
                .ok_or_else(|| anyhow::anyhow!("unknown --kernels {k:?} (compound | output | simd)"))?;
            trainer = trainer.with_kernels(kernels);
        }
        if let Some(s) = args.get("selection") {
            let sel = dsg::drs::SelectionMode::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown --selection {s:?} (unstructured | structured[:blocked])")
            })?;
            trainer = trainer.with_selection(sel);
        }
        let acc = trainer.train_opts(&cfg, &train, &test, &opts)?;
        println!("shards ({shards}):");
        for (s, st) in trainer.shard_stats().iter().enumerate() {
            println!(
                "  shard {s}: {} leaf steps, {} retries{}",
                st.leaves_done,
                st.retries,
                if st.alive { "" } else { " (LOST)" }
            );
        }
        if trainer.reshards() > 0 {
            println!("  reshard events: {}", trainer.reshards());
        }
        let w = trainer.wire_stats();
        if w.grad_dense_bytes > 0 {
            println!(
                "gradient exchange: {} on wire vs {} dense -> {:.2}x (frames {})",
                dsg::util::human_bytes(w.grad_wire_bytes as usize),
                dsg::util::human_bytes(w.grad_dense_bytes as usize),
                w.ratio(),
                dsg::util::human_bytes(w.frame_bytes as usize)
            );
        }
        let dens = trainer.history.mean_densities(20);
        if !dens.is_empty() {
            let joined: Vec<String> = dens.iter().map(|d| format!("{d:.3}")).collect();
            println!(
                "mean mask density over last 20 steps: [{}] (target {:.3})",
                joined.join(", "),
                1.0 - cfg.gamma.target()
            );
        }
        (acc, trainer.history, trainer.state)
    } else if engine == "native" {
        let mut trainer = dsg::coordinator::NativeTrainer::new(meta, cfg.seed)?;
        if let Some(t) = args.get_usize("threads")? {
            trainer = trainer.with_threads(t.max(1));
        }
        if let Some(t) = args.get("tape") {
            let tape = native::train::TapeStorage::parse(t)
                .ok_or_else(|| anyhow::anyhow!("unknown --tape {t:?} (dense | zvc)"))?;
            trainer = trainer.with_tape(tape);
        }
        if let Some(k) = args.get("kernels") {
            let kernels = sparse::parallel::SparseKernels::parse(k)
                .ok_or_else(|| anyhow::anyhow!("unknown --kernels {k:?} (compound | output | simd)"))?;
            trainer = trainer.with_kernels(kernels);
        }
        if let Some(s) = args.get("selection") {
            let sel = dsg::drs::SelectionMode::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown --selection {s:?} (unstructured | structured[:blocked])")
            })?;
            trainer = trainer.with_selection(sel);
        }
        let acc = trainer.train_opts(&cfg, &train, &test, &opts)?;
        // measured training-tape footprint of the final step (Fig 6 made
        // real: peak bytes the backward actually needed, vs dense)
        let mem = trainer.tape_memory();
        if mem.peak() > 0 {
            // sparsity is only measured on the ZVC tape (the dense tape
            // deliberately skips the counting sweep)
            let acts = if mem.act_reduction() > 1.0 {
                format!(
                    " (acts {:.2}x at {:.0}% measured sparsity)",
                    mem.act_reduction(),
                    100.0 * mem.act_sparsity()
                )
            } else {
                String::new()
            };
            println!(
                "tape memory (last step): peak {} vs dense {} -> {:.2}x{acts}",
                dsg::util::human_bytes(mem.peak()),
                dsg::util::human_bytes(mem.dense_peak()),
                mem.reduction()
            );
        }
        // measured Fig 9: multiply-adds the compound kernels actually
        // executed in the final step vs the dense-equivalent baseline
        let ops = trainer.ops();
        if ops.total_dense() > 0 {
            println!("realized ops (last step): {}", ops.summary());
            let per: Vec<String> = ops
                .layers()
                .iter()
                .map(|l| format!("{} {:.2}x", l.name.trim_start_matches("params."), l.reduction()))
                .collect();
            println!("  per layer: [{}]", per.join(", "));
        }
        // per-layer density report: the paper's 1-gamma tracking
        let dens = trainer.history.mean_densities(20);
        if !dens.is_empty() {
            let joined: Vec<String> = dens.iter().map(|d| format!("{d:.3}")).collect();
            println!(
                "mean mask density over last 20 steps: [{}] (target {:.3})",
                joined.join(", "),
                1.0 - cfg.gamma.target()
            );
        }
        (acc, trainer.history, trainer.state)
    } else {
        let rt = Runtime::cpu()?;
        let mut trainer = Trainer::new(&rt, meta, cfg.seed)?;
        let acc = trainer.train_opts(&cfg, &train, &test, &opts)?;
        (acc, trainer.history, trainer.state)
    };
    println!(
        "done: final eval acc {:.3}, last loss {:.4}, {:.1}s total step time",
        acc,
        history.last_loss().unwrap_or(f32::NAN),
        history.total_secs()
    );
    // stable FNV digest of every weight bit: lets CI (and humans)
    // assert crash-resumed runs end bit-identical to clean ones
    println!("state digest: {:016x}", state.digest());
    let rec = dsg::metrics::recovery().snapshot();
    if rec.any() {
        println!("recovery: {}", rec.summary());
    }
    if let Some(csv) = args.get("csv") {
        history.write_csv(std::path::Path::new(csv))?;
        println!("wrote history to {csv}");
    }
    if let Some(ck) = args.get("checkpoint") {
        dsg::coordinator::checkpoint::save(std::path::Path::new(ck), &state)?;
        println!("wrote checkpoint to {ck}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let ck = args.get("checkpoint").context("--checkpoint required")?;
    let gamma = args.get_f32("gamma")?.unwrap_or(0.5);
    let dir = dsg::artifacts_dir();
    let rt = Runtime::cpu()?;
    let meta = Meta::load(&dir, model)?;
    let cfg = RunConfig::preset_for_model(model);
    let full = if cfg.dataset == "fashion" {
        datasets::fashion_like(cfg.test_size, cfg.seed + 1)
    } else {
        datasets::cifar_like(cfg.test_size, cfg.seed + 1)
    };
    let mut trainer = Trainer::new(&rt, meta, cfg.seed)?;
    trainer.state = dsg::coordinator::checkpoint::load(std::path::Path::new(ck))?;
    let acc = trainer.evaluate(&full, gamma)?;
    println!("{model} @ gamma {gamma}: eval acc {acc:.3}");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = dsg::artifacts_dir();
    match args.get("model") {
        None => {
            let variants = Meta::list_variants(&dir)?;
            println!("artifacts dir: {dir:?}\nvariants ({}):", variants.len());
            for v in variants {
                let m = Meta::load(&dir, &v)?;
                println!(
                    "  {:16} batch {:3}  params {:>9}  dsg layers {:2}  strategy {}",
                    m.name,
                    m.batch,
                    m.param_elems(),
                    m.counts.dsg,
                    m.strategy
                );
            }
        }
        Some(name) => {
            let m = Meta::load(&dir, name)?;
            println!("{}: base {}, batch {}, classes {}", m.name, m.base_model, m.batch, m.classes);
            println!("  opts: eps {} strategy {} double_mask {} bn {}", m.eps, m.strategy, m.double_mask, m.use_bn);
            println!("  files: {:?}", m.files.keys().collect::<Vec<_>>());
            println!("  state leaves: {} ({} params elems)", m.state.len(), m.param_elems());
            for l in &m.dsg_layers {
                println!(
                    "  dsg {:10} d_in {:5} -> k {:4} ({}x reduction), n_out {}",
                    l.path,
                    l.d_in,
                    l.k,
                    l.d_in / l.k.max(1),
                    l.n_out
                );
            }
        }
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let gamma = args.get_f32("gamma")?.unwrap_or(0.8) as f64;
    let s = memmodel::effective_sparsity(gamma, 0.5);
    println!("Fig 6 memory report @ mask sparsity {gamma} (activation sparsity {s:.2})\n");
    println!(
        "{:<10} {:>6} {:>12} {:>12} {:>12} {:>8} {:>8} {:>8}",
        "model", "batch", "dense-train", "dsg-train", "weights", "train-x", "act-x", "infer-x"
    );
    for net in costmodel::shapes::fig6_nets() {
        let m = memmodel::memory(&net, s);
        println!(
            "{:<10} {:>6} {:>12} {:>12} {:>12} {:>7.2}x {:>7.2}x {:>7.2}x",
            net.name,
            net.batch,
            dsg::util::human_bytes(m.train_dense()),
            dsg::util::human_bytes(m.train_dsg()),
            dsg::util::human_bytes(m.weights),
            m.train_reduction(),
            m.act_reduction(),
            m.infer_reduction()
        );
    }
    Ok(())
}

fn cmd_compute(args: &Args) -> Result<()> {
    let gamma = args.get_f32("gamma")?.unwrap_or(0.8) as f64;
    let eps = args.get_f32("eps")?.unwrap_or(0.5) as f64;
    println!("Fig 7 compute report @ gamma {gamma}, eps {eps}\n");
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8} {:>10}",
        "model", "train-GM", "dsgtr-GM", "train-x", "infer-GM", "dsginf-GM", "infer-x", "drs-ovh"
    );
    for net in costmodel::shapes::fig6_nets() {
        let m = costmodel::macs(&net, gamma, eps);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>7.2}x {:>10.2} {:>10.2} {:>7.2}x {:>9.1}%",
            net.name,
            costmodel::gmacs(m.train_dense()),
            costmodel::gmacs(m.train_dsg()),
            m.train_reduction(),
            costmodel::gmacs(m.fwd_dense),
            costmodel::gmacs(m.fwd_dsg),
            m.infer_reduction(),
            100.0 * m.search_frac_infer()
        );
    }
    Ok(())
}

fn cmd_speed(args: &Args) -> Result<()> {
    let gamma = args.get_f32("gamma")?.unwrap_or(0.8);
    let reps = args.get_usize("reps")?.unwrap_or(3);
    println!("Fig 8a layer timings @ gamma {gamma} ({reps} reps, median)\n");
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>9} {:>9} {:>8}",
        "layer", "GEMM", "VMM", "DSG", "vs-VMM", "vs-GEMM", "density"
    );
    for &shape in sparse::engine::VGG8_LAYERS {
        let t = sparse::engine::bench_layer(shape, gamma, 0.5, reps, 7);
        println!(
            "{:<8} {:>10} {:>10} {:>10} {:>8.2}x {:>8.2}x {:>8.2}",
            shape.name,
            dsg::metrics::fmt_secs(t.gemm_secs),
            dsg::metrics::fmt_secs(t.vmm_secs),
            dsg::metrics::fmt_secs(t.dsg_secs),
            t.speedup_vs_vmm(),
            t.speedup_vs_gemm(),
            t.density
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let parse_list = |s: &str| -> Vec<String> {
        s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
    };
    let variants = parse_list(args.get("models").unwrap_or("mlp"));
    let gammas: Vec<f32> = parse_list(args.get("gammas").unwrap_or("0,0.5,0.8"))
        .iter()
        .map(|g| g.parse().with_context(|| format!("gamma {g:?}")))
        .collect::<Result<_>>()?;
    let seeds: Vec<u64> = parse_list(args.get("seeds").unwrap_or("7"))
        .iter()
        .map(|s| s.parse().with_context(|| format!("seed {s:?}")))
        .collect::<Result<_>>()?;
    let steps = args.get_usize("steps")?.unwrap_or(120);
    let sweep = dsg::coordinator::sweep::Sweep { variants, gammas, seeds, steps };
    println!("sweep: {} runs of {steps} steps", sweep.points().len());
    let rt = Runtime::cpu()?;
    let results = sweep.run(&rt, true)?;
    println!("\n{:<16} {:>8} {:>10} {:>8}", "variant", "gamma", "mean-acc", "std");
    for (v, g, mean, std) in dsg::coordinator::sweep::aggregate(&results) {
        println!("{v:<16} {g:>8.2} {mean:>10.3} {std:>8.3}");
    }
    if let Some(p) = args.get("csv") {
        dsg::coordinator::sweep::write_csv(std::path::Path::new(p), &results)?;
        println!("wrote {p}");
    }
    if let Some(p) = args.get("json") {
        std::fs::write(p, dsg::coordinator::sweep::to_json(&results).to_string())?;
        println!("wrote {p}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("synthetic").to_string();
    let requests = args.get_usize("requests")?.unwrap_or(512);
    let cores = sparse::parallel::n_threads();
    let workers = args.get_usize("workers")?.unwrap_or_else(|| cores.min(4)).max(1);
    let gamma = args.get_f32("gamma")?.unwrap_or(0.5);
    anyhow::ensure!(
        (0.0..1.0).contains(&gamma),
        "--gamma must be in [0, 1), got {gamma}"
    );
    let max_wait_ms = args.get_f32("max-wait-ms")?.unwrap_or(5.0).max(0.0) as f64;
    let seed = args.get_usize("seed")?.unwrap_or(7) as u64;
    let selection = match args.get("selection") {
        Some(s) => dsg::drs::SelectionMode::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --selection {s:?} (unstructured | structured[:blocked])")
        })?,
        None => dsg::drs::SelectionMode::default(),
    };
    // serving dispatches on the kernel TABLE behind the mode, so only
    // the table-distinct modes are meaningful flags here
    let kernels = match args.get("kernels") {
        Some(k) => match sparse::parallel::SparseKernels::parse(k) {
            Some(kk @ sparse::parallel::SparseKernels::Compound)
            | Some(kk @ sparse::parallel::SparseKernels::Simd) => kk,
            _ => anyhow::bail!("unknown --kernels {k:?} (compound | simd)"),
        },
        None => sparse::parallel::SparseKernels::default(),
    };
    // split the core budget across workers; the parallel engines are
    // bit-exact under any split, so predictions don't depend on this
    let intra = (cores / workers).max(1);

    // Build the forward fn + deterministic request images.  `ops_meter`
    // aggregates realized vs dense-equivalent multiply-adds across every
    // worker (the serve-side Fig 9 number).
    let (forward, images, max_batch, input_elems, classes, ops_meter): (
        Box<dyn Fn(&[f32]) -> Result<Vec<f32>> + Send + Sync>,
        Vec<Vec<f32>>,
        usize,
        usize,
        usize,
        std::sync::Arc<dsg::metrics::OpsMeter>,
    ) = if model == "synthetic" {
        let data = datasets::fashion_like(requests.max(1), seed);
        let d = data.input_elems();
        let max_batch = args.get_usize("max-batch")?.unwrap_or(32);
        let m = SynthModel::new(seed, &[d, 512, 256], 10, gamma)
            .with_intra_threads(intra)
            .with_selection(selection)
            .with_kernels(kernels);
        let ops = m.ops_meter();
        let images: Vec<Vec<f32>> = datasets::BatchIter::eval_batches(&data, 1)
            .into_iter()
            .take(requests)
            .map(|(xs, _, _)| xs)
            .collect();
        let classes = m.classes;
        let fwd = move |xs: &[f32]| m.forward(xs, max_batch);
        (Box::new(fwd), images, max_batch, d, classes, ops)
    } else {
        let dir = dsg::artifacts_dir();
        let meta = Meta::load(&dir, &model)?;
        let max_batch = args.get_usize("max-batch")?.unwrap_or(meta.batch);
        let mut state = match args.get("checkpoint") {
            Some(ck) => dsg::coordinator::checkpoint::load(std::path::Path::new(ck))?,
            None => {
                dsg::warn!("no --checkpoint: serving randomly initialized {model} weights");
                dsg::coordinator::ModelState::init(&meta, seed)
            }
        };
        native::project_host(&meta, &mut state)?;
        let nm = native::NativeModel::new(&meta, &state)?
            .with_selection(selection)
            .with_kernels(kernels);
        let cfg = RunConfig::preset_for_model(&model);
        let data = if cfg.dataset == "fashion" {
            datasets::fashion_like(requests.max(1), seed)
        } else {
            datasets::cifar_like(requests.max(1), seed)
        };
        let d = meta.input_elems();
        let classes = meta.classes;
        let mut shape = vec![max_batch];
        shape.extend_from_slice(&meta.input_shape);
        let images: Vec<Vec<f32>> = datasets::BatchIter::eval_batches(&data, 1)
            .into_iter()
            .take(requests)
            .map(|(xs, _, _)| xs)
            .collect();
        let ops = std::sync::Arc::new(dsg::metrics::OpsMeter::new());
        let ops_in = ops.clone();
        let fwd = move |xs: &[f32]| -> Result<Vec<f32>> {
            let xt = dsg::Tensor::new(&shape, xs.to_vec());
            let out = nm.forward_threaded(&xt, gamma, native::Mode::Dsg, intra)?;
            for s in &out.stats {
                ops_in.add(s.realized_madds, s.dense_madds);
            }
            Ok(out.logits.into_data())
        };
        (Box::new(fwd), images, max_batch, d, classes, ops)
    };

    anyhow::ensure!(max_batch > 0, "--max-batch must be at least 1");
    let max_wait = std::time::Duration::from_secs_f64(max_wait_ms / 1e3);

    // ---- client mode: drive a listening server over the wire --------
    if let Some(addr) = args.get("connect") {
        let ep = Endpoint::parse(addr);
        println!("connecting to {ep}: {} requests", images.len());
        connect_retry(&ep, std::time::Duration::from_secs(10))?;
        let copts = ClientOptions {
            shutdown_after: args.get("shutdown").is_some(),
            retries: args.get_usize("retries")?.unwrap_or(0),
            seed,
            ..Default::default()
        };
        let run = drive_load_with(&ep, &images, &copts)?;
        let p = dsg::serve::ServeStats {
            latencies: run.rtt.clone(),
            ..Default::default()
        };
        let pct = p.percentiles(&[0.5, 0.99]);
        println!(
            "client: {} served, {} rejected, {} errors, {} retried in {:.3}s \
             ({:.1} req/s); rtt-bound p50 {} p99 {}",
            run.served(),
            run.rejected(),
            run.events.len() - run.served() - run.rejected(),
            run.retries,
            run.wall,
            run.events.len() as f64 / run.wall.max(1e-12),
            fmt_secs(pct[0]),
            fmt_secs(pct[1]),
        );
        let rec = dsg::metrics::recovery().snapshot();
        if rec.any() {
            println!("recovery: {}", rec.summary());
        }
        if args.get("verify").is_some() {
            anyhow::ensure!(
                model == "synthetic",
                "--verify needs the synthetic model (identical weights on both sides)"
            );
            let cfg = ShardedConfig::new(1, 1, max_batch, input_elems, classes);
            let reference = ShardedServer::serve_all(cfg, forward, images)?;
            anyhow::ensure!(
                run.predictions() == reference.predictions(),
                "socket predictions DIVERGED from in-process serving"
            );
            println!(
                "verify: {} socket predictions bit-identical to in-process serving",
                reference.served
            );
        }
        return Ok(());
    }

    // ---- server mode: expose the sharded engine on a socket ---------
    if let Some(addr) = args.get("listen") {
        let shards = args.get_usize("shards")?.unwrap_or(workers).max(1);
        let cfg = ShardedConfig::new(shards, workers, max_batch, input_elems, classes)
            .with_max_wait(max_wait)
            .with_queue_cap(args.get_usize("queue-cap")?.unwrap_or(0))
            .with_density_shaping(args.get("no-shaping").is_none());
        let mut tuning = ServerTuning::default();
        if let Some(ms) = args.get_usize("idle-ms")? {
            tuning.idle_timeout = std::time::Duration::from_millis(ms as u64);
        }
        if let Some(q) = args.get_usize("write-queue")? {
            tuning.write_queue = q.max(1);
        }
        let server = WireServer::bind_tuned(&Endpoint::parse(addr), cfg, tuning, forward)?;
        println!(
            "listening on {} ({shards} shards x {workers} workers, batch {max_batch}, \
             max-wait {max_wait_ms}ms, gamma {gamma}); send Shutdown to stop",
            server.local_endpoint()
        );
        let report = server.run()?;
        print_shard_report(&report, max_batch);
        if ops_meter.dense() > 0 {
            println!("realized ops (all batches): {}", ops_meter.summary());
        }
        let rec = dsg::metrics::recovery().snapshot();
        if rec.any() {
            println!("recovery: {}", rec.summary());
        }
        return Ok(());
    }

    // ---- in-process sharded mode ------------------------------------
    if let Some(shards) = args.get_usize("shards")? {
        let shards = shards.max(1);
        println!(
            "serving {model} [sharded]: {} requests, {shards} shards x {workers} workers \
             x {intra} engine threads, batch {max_batch}, gamma {gamma}",
            images.len()
        );
        let cfg = ShardedConfig::new(shards, workers, max_batch, input_elems, classes)
            .with_max_wait(max_wait)
            .with_queue_cap(args.get_usize("queue-cap")?.unwrap_or(0))
            .with_density_shaping(args.get("no-shaping").is_none());
        let report = ShardedServer::serve_all(cfg, forward, images)?;
        print_shard_report(&report, max_batch);
        if ops_meter.dense() > 0 {
            println!("realized ops (all batches): {}", ops_meter.summary());
        }
        let rec = dsg::metrics::recovery().snapshot();
        if rec.any() {
            println!("recovery: {}", rec.summary());
        }
        return Ok(());
    }

    // ---- legacy single-queue mode (the baseline) --------------------
    println!(
        "serving {model}: {} requests, {workers} workers x {intra} engine threads, \
         batch {max_batch}, max-wait {max_wait_ms}ms, gamma {gamma}",
        images.len()
    );
    let cfg = ServerConfig::new(workers, max_batch, input_elems, classes).with_max_wait(max_wait);
    // pre-enqueued drain: batch boundaries (and so predictions) are
    // deterministic for any worker count
    let report = ConcurrentServer::serve_all(cfg, forward, images)?;

    println!(
        "\n{:>10} {:>8} {:>7} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "served", "batches", "padded", "p50", "p95", "p99", "mean", "imgs/sec"
    );
    println!(
        "{:>10} {:>8} {:>7} {:>10} {:>10} {:>10} {:>10} {:>12.1}",
        report.served,
        report.batches,
        report.padded_slots,
        fmt_secs(report.latency.percentile(0.50)),
        fmt_secs(report.latency.percentile(0.95)),
        fmt_secs(report.latency.percentile(0.99)),
        fmt_secs(report.latency.mean()),
        report.throughput()
    );
    println!(
        "compute/batch ({max_batch} imgs): {}  wall {:.3}s",
        report.compute.summary(),
        report.wall
    );
    if ops_meter.dense() > 0 {
        println!("realized ops (all batches): {}", ops_meter.summary());
    }
    Ok(())
}

/// Shared summary printer for the sharded serving paths.
fn print_shard_report(report: &dsg::serve::ShardReport, max_batch: usize) {
    println!(
        "\n{:>10} {:>8} {:>8} {:>7} {:>7} {:>10} {:>10} {:>10} {:>12}",
        "served", "rejected", "batches", "padded", "stolen", "p50", "p95", "p99", "imgs/sec"
    );
    println!(
        "{:>10} {:>8} {:>8} {:>7} {:>7} {:>10} {:>10} {:>10} {:>12.1}",
        report.served,
        report.rejected,
        report.batches,
        report.padded_slots,
        report.stolen,
        fmt_secs(report.latency.percentile(0.50)),
        fmt_secs(report.latency.percentile(0.95)),
        fmt_secs(report.latency.percentile(0.99)),
        report.throughput()
    );
    println!(
        "compute/batch ({max_batch} imgs): {}  wall {:.3}s",
        report.compute.summary(),
        report.wall
    );
    if report.retries > 0 {
        println!("  batch retries: {} (transient forward faults absorbed)", report.retries);
    }
    for (i, s) in report.per_shard.iter().enumerate() {
        println!(
            "  shard {i}: {} blocks in, {} home, {} stolen, {} rejected, peak depth {}",
            s.enqueued, s.taken_home, s.stolen, s.rejected, s.peak_depth
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            std::process::exit(2);
        }
    };
    let result = match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "memory" => cmd_memory(&args),
        "compute" => cmd_compute(&args),
        "speed" => cmd_speed(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "help" | "-h" | "--help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
