//! Data augmentation for the synthetic pipelines: random horizontal
//! flip, random crop with zero padding, and cutout.  Standard CIFAR
//! training recipe; applied on the fly by `AugmentIter`.

use super::Dataset;
use crate::util::Pcg32;

/// Augmentation config.
#[derive(Clone, Copy, Debug)]
pub struct Augment {
    pub hflip: bool,
    /// pad-and-crop jitter radius in pixels (0 = off)
    pub crop_pad: usize,
    /// cutout square size (0 = off)
    pub cutout: usize,
}

impl Default for Augment {
    fn default() -> Self {
        Augment { hflip: true, crop_pad: 2, cutout: 0 }
    }
}

/// Horizontal flip of a (C, H, W) image.
pub fn hflip(img: &[f32], c: usize, h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; img.len()];
    for ci in 0..c {
        for y in 0..h {
            for x in 0..w {
                out[(ci * h + y) * w + x] = img[(ci * h + y) * w + (w - 1 - x)];
            }
        }
    }
    out
}

/// Shift a (C, H, W) image by (dy, dx), zero-filling.
pub fn shift(img: &[f32], c: usize, h: usize, w: usize, dy: isize, dx: isize) -> Vec<f32> {
    let mut out = vec![0.0f32; img.len()];
    for ci in 0..c {
        for y in 0..h {
            let sy = y as isize - dy;
            if sy < 0 || sy >= h as isize {
                continue;
            }
            for x in 0..w {
                let sx = x as isize - dx;
                if sx < 0 || sx >= w as isize {
                    continue;
                }
                out[(ci * h + y) * w + x] = img[(ci * h + sy as usize) * w + sx as usize];
            }
        }
    }
    out
}

/// Zero out a square patch (cutout regularization).
pub fn cutout(img: &mut [f32], c: usize, h: usize, w: usize, cy: usize, cx: usize, size: usize) {
    let y0 = cy.saturating_sub(size / 2);
    let x0 = cx.saturating_sub(size / 2);
    for ci in 0..c {
        for y in y0..(y0 + size).min(h) {
            for x in x0..(x0 + size).min(w) {
                img[(ci * h + y) * w + x] = 0.0;
            }
        }
    }
}

/// Apply the augmentation pipeline to one image.
pub fn apply(aug: &Augment, rng: &mut Pcg32, img: &[f32], shape: &[usize]) -> Vec<f32> {
    let (c, h, w) = (shape[0], shape[1], shape[2]);
    let mut out = img.to_vec();
    if aug.hflip && rng.uniform() < 0.5 {
        out = hflip(&out, c, h, w);
    }
    if aug.crop_pad > 0 {
        let r = aug.crop_pad as isize;
        let dy = rng.below((2 * aug.crop_pad + 1) as u32) as isize - r;
        let dx = rng.below((2 * aug.crop_pad + 1) as u32) as isize - r;
        if dy != 0 || dx != 0 {
            out = shift(&out, c, h, w, dy, dx);
        }
    }
    if aug.cutout > 0 {
        let cy = rng.below(h as u32) as usize;
        let cx = rng.below(w as u32) as usize;
        cutout(&mut out, c, h, w, cy, cx, aug.cutout);
    }
    out
}

/// Batch iterator with on-the-fly augmentation.
pub struct AugmentIter<'a> {
    inner: super::BatchIter<'a>,
    aug: Augment,
    shape: Vec<usize>,
    rng: Pcg32,
}

impl<'a> AugmentIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, aug: Augment, seed: u64) -> Self {
        assert_eq!(data.input_shape.len(), 3, "augmentation needs (C,H,W) data");
        AugmentIter {
            inner: super::BatchIter::new(data, batch, seed),
            aug,
            shape: data.input_shape.clone(),
            rng: Pcg32::seeded(seed ^ 0xa0621),
        }
    }

    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let (xs, ys) = self.inner.next_batch();
        let per: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(xs.len());
        for img in xs.chunks_exact(per) {
            out.extend(apply(&self.aug, &mut self.rng, img, &self.shape));
        }
        (out, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hflip_involution() {
        let img: Vec<f32> = (0..2 * 3 * 4).map(|i| i as f32).collect();
        let f = hflip(&img, 2, 3, 4);
        assert_ne!(f, img);
        assert_eq!(hflip(&f, 2, 3, 4), img);
    }

    #[test]
    fn shift_moves_mass() {
        let mut img = vec![0.0f32; 16];
        img[5] = 1.0; // (1, 1) in 4x4
        let s = shift(&img, 1, 4, 4, 1, 0);
        assert_eq!(s[9], 1.0); // moved to (2, 1)
        assert_eq!(s[5], 0.0);
        // shifting off the edge zeroes
        let far = shift(&img, 1, 4, 4, 10, 0);
        assert!(far.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn cutout_zeroes_patch() {
        let mut img = vec![1.0f32; 1 * 6 * 6];
        cutout(&mut img, 1, 6, 6, 3, 3, 2);
        let zeros = img.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 4);
    }

    #[test]
    fn identity_when_disabled() {
        let aug = Augment { hflip: false, crop_pad: 0, cutout: 0 };
        let mut rng = Pcg32::seeded(1);
        let img: Vec<f32> = (0..27).map(|i| i as f32).collect();
        assert_eq!(apply(&aug, &mut rng, &img, &[3, 3, 3]), img);
    }

    #[test]
    fn augment_iter_shapes_and_determinism() {
        let d = crate::datasets::cifar_like(32, 3);
        let aug = Augment::default();
        let mut a = AugmentIter::new(&d, 8, aug, 9);
        let mut b = AugmentIter::new(&d, 8, aug, 9);
        let (xa, ya) = a.next_batch();
        let (xb, yb) = b.next_batch();
        assert_eq!(xa.len(), 8 * 3 * 32 * 32);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        // different seed differs
        let mut c = AugmentIter::new(&d, 8, aug, 10);
        let (xc, _) = c.next_batch();
        assert_ne!(xa, xc);
    }
}
