//! Synthetic dataset substrate (substitution for FASHION /
//! CIFAR10 — no dataset downloads in this environment).
//!
//! Each class is a procedurally generated template bank; samples are a
//! random template + random shift + elastic-ish channel jitter + pixel
//! noise.  The task is genuinely learnable but not trivial (class
//! templates overlap through noise), which is what the sparsity-accuracy
//! experiments need: a loss surface where pruning too much *hurts*.

pub mod augment;

use crate::tensor::Tensor;
use crate::util::Pcg32;

/// A labelled dataset of flattened f32 images.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// (C, H, W) — (1, 28, 28) fashion-like, (3, 32, 32) cifar-like.
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Split into (train, test).
    pub fn split(mut self, test_frac: f64) -> (Dataset, Dataset) {
        let n_test = (self.len() as f64 * test_frac) as usize;
        let n_train = self.len() - n_test;
        let test = Dataset {
            name: format!("{}-test", self.name),
            input_shape: self.input_shape.clone(),
            n_classes: self.n_classes,
            images: self.images.split_off(n_train),
            labels: self.labels.split_off(n_train),
        };
        self.name = format!("{}-train", self.name);
        (self, test)
    }
}

/// Deterministic batch iterator with per-epoch reshuffling.
pub struct BatchIter<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Pcg32,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && batch <= data.len(), "batch {batch} of {}", data.len());
        let mut rng = Pcg32::seeded(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { data, batch, order, pos: 0, rng }
    }

    /// Next batch as (x flat (batch * input_elems), y (batch)); wraps
    /// epochs, reshuffling at each boundary.
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let d = self.data.input_elems();
        let mut xs = Vec::with_capacity(self.batch * d);
        let mut ys = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.pos == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
            }
            let i = self.order[self.pos];
            self.pos += 1;
            xs.extend_from_slice(&self.data.images[i]);
            ys.push(self.data.labels[i]);
        }
        (xs, ys)
    }

    /// Advance the iterator past `n` full batches WITHOUT materializing
    /// them — the O(steps) resume fast-forward.  Bit-identical to
    /// calling [`BatchIter::next_batch`] `n` times and discarding the
    /// results: the epoch-boundary reshuffle is replayed at exactly the
    /// per-draw positions `next_batch` would hit (the reshuffle happens
    /// lazily BEFORE a draw, never after the last draw of an epoch), so
    /// the RNG stream and cursor land in the identical state.  The only
    /// work is the inherent per-epoch reshuffles; no image bytes are
    /// copied.
    pub fn skip_batches(&mut self, n: usize) {
        let mut remaining = n.saturating_mul(self.batch);
        while remaining > 0 {
            if self.pos == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
            }
            let take = remaining.min(self.order.len() - self.pos);
            self.pos += take;
            remaining -= take;
        }
    }

    /// Sequential (unshuffled) batches covering the set once; the last
    /// partial batch is padded by wrapping to the front.
    pub fn eval_batches(data: &'a Dataset, batch: usize) -> Vec<(Vec<f32>, Vec<i32>, usize)> {
        let d = data.input_elems();
        let mut out = Vec::new();
        let mut i = 0;
        while i < data.len() {
            let valid = batch.min(data.len() - i);
            let mut xs = Vec::with_capacity(batch * d);
            let mut ys = Vec::with_capacity(batch);
            for j in 0..batch {
                let idx = if j < valid { i + j } else { j - valid };
                xs.extend_from_slice(&data.images[idx]);
                ys.push(data.labels[idx]);
            }
            out.push((xs, ys, valid));
            i += valid;
        }
        out
    }
}

fn gen_templates(
    rng: &mut Pcg32,
    n_classes: usize,
    per_class: usize,
    c: usize,
    h: usize,
    w: usize,
) -> Vec<Vec<Vec<f32>>> {
    // Per class: `per_class` smooth random templates built from a few
    // random blobs + stripes, giving classes distinct spatial structure.
    let mut banks = Vec::with_capacity(n_classes);
    for _ in 0..n_classes {
        let mut bank = Vec::with_capacity(per_class);
        // class-level structure shared by its templates
        let n_blobs = 2 + rng.below(3) as usize;
        let blobs: Vec<(f32, f32, f32, f32)> = (0..n_blobs)
            .map(|_| {
                (
                    rng.uniform_in(0.2, 0.8) * h as f32,
                    rng.uniform_in(0.2, 0.8) * w as f32,
                    rng.uniform_in(2.0, 6.0),
                    rng.uniform_in(0.6, 1.4),
                )
            })
            .collect();
        let stripe_freq = rng.uniform_in(0.2, 0.9);
        let stripe_phase = rng.uniform_in(0.0, 6.28);
        for _ in 0..per_class {
            let jitter_y = rng.uniform_in(-1.5, 1.5);
            let jitter_x = rng.uniform_in(-1.5, 1.5);
            let mut img = vec![0.0f32; c * h * w];
            for ci in 0..c {
                let ch_gain = 0.7 + 0.3 * ((ci as f32 + 1.0) * stripe_phase).sin();
                for y in 0..h {
                    for x in 0..w {
                        let mut v = 0.0f32;
                        for &(by, bx, bs, ba) in &blobs {
                            let dy = y as f32 - by - jitter_y;
                            let dx = x as f32 - bx - jitter_x;
                            v += ba * (-(dy * dy + dx * dx) / (2.0 * bs * bs)).exp();
                        }
                        v += 0.15 * (stripe_freq * (y as f32 + x as f32) + stripe_phase).sin();
                        img[(ci * h + y) * w + x] = v * ch_gain;
                    }
                }
            }
            bank.push(img);
        }
        banks.push(bank);
    }
    banks
}

fn synth(
    name: &str,
    rng_seed: u64,
    n: usize,
    n_classes: usize,
    c: usize,
    h: usize,
    w: usize,
    noise: f32,
) -> Dataset {
    let mut rng = Pcg32::seeded(rng_seed);
    let banks = gen_templates(&mut rng, n_classes, 4, c, h, w);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let d = c * h * w;
    for _ in 0..n {
        let cls = rng.below(n_classes as u32) as usize;
        let t = &banks[cls][rng.below(4) as usize];
        let mut img = vec![0.0f32; d];
        // random +-2 pixel translation
        let sy = rng.below(5) as isize - 2;
        let sx = rng.below(5) as isize - 2;
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let yy = y as isize + sy;
                    let xx = x as isize + sx;
                    let v = if yy >= 0 && (yy as usize) < h && xx >= 0 && (xx as usize) < w
                    {
                        t[(ci * h + yy as usize) * w + xx as usize]
                    } else {
                        0.0
                    };
                    img[(ci * h + y) * w + x] = v + noise * rng.normal();
                }
            }
        }
        // normalize roughly to zero mean unit-ish scale
        let mean: f32 = img.iter().sum::<f32>() / d as f32;
        for v in img.iter_mut() {
            *v = (*v - mean) * 2.0;
        }
        images.push(img);
        labels.push(cls as i32);
    }
    Dataset {
        name: name.to_string(),
        input_shape: vec![c, h, w],
        n_classes,
        images,
        labels,
    }
}

/// FASHION-like: 10-class (1, 28, 28) grayscale.
pub fn fashion_like(n: usize, seed: u64) -> Dataset {
    synth("fashion-like", seed, n, 10, 1, 28, 28, 0.25)
}

/// CIFAR-like: 10-class (3, 32, 32) RGB.
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    synth("cifar-like", seed, n, 10, 3, 32, 32, 0.30)
}

/// A batch as a Tensor (batch, C*H*W) — handy for host-side engines.
pub fn batch_tensor(xs: &[f32], batch: usize) -> Tensor {
    let d = xs.len() / batch;
    Tensor::new(&[batch, d], xs.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = fashion_like(32, 9);
        let b = fashion_like(32, 9);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = fashion_like(32, 10);
        assert_ne!(a.images[0], c.images[0]);
    }

    #[test]
    fn shapes_and_labels() {
        let d = cifar_like(64, 1);
        assert_eq!(d.input_shape, vec![3, 32, 32]);
        assert_eq!(d.images[0].len(), 3 * 32 * 32);
        assert!(d.labels.iter().all(|&l| (0..10).contains(&l)));
        // all classes present in 64 draws (w.h.p.)
        let mut seen = [false; 10];
        for &l in &d.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-centroid classification on raw pixels must beat chance
        // by a lot — otherwise the sparsity-accuracy benches measure noise.
        let d = fashion_like(600, 3);
        let (train, test) = d.split(0.25);
        let dim = train.input_elems();
        let mut centroids = vec![vec![0.0f64; dim]; 10];
        let mut counts = [0usize; 10];
        for (img, &l) in train.images.iter().zip(&train.labels) {
            counts[l as usize] += 1;
            for (a, &b) in centroids[l as usize].iter_mut().zip(img) {
                *a += b as f64;
            }
        }
        for (cvec, &n) in centroids.iter_mut().zip(&counts) {
            for v in cvec.iter_mut() {
                *v /= n.max(1) as f64;
            }
        }
        let mut correct = 0;
        for (img, &l) in test.images.iter().zip(&test.labels) {
            let mut best = (f64::INFINITY, 0usize);
            for (ci, cvec) in centroids.iter().enumerate() {
                let dist: f64 = img
                    .iter()
                    .zip(cvec)
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, ci);
                }
            }
            if best.1 == l as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-centroid acc only {acc}");
    }

    #[test]
    fn split_partitions() {
        let d = fashion_like(100, 4);
        let (tr, te) = d.split(0.2);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }

    #[test]
    fn batch_iter_wraps_and_reshuffles() {
        let d = fashion_like(10, 5);
        let mut it = BatchIter::new(&d, 4, 0);
        let mut labels_seen = Vec::new();
        for _ in 0..5 {
            let (xs, ys) = it.next_batch();
            assert_eq!(xs.len(), 4 * d.input_elems());
            assert_eq!(ys.len(), 4);
            labels_seen.extend(ys);
        }
        assert_eq!(labels_seen.len(), 20); // wrapped past 10 twice
    }

    #[test]
    fn skip_batches_matches_drawn_stream() {
        // the fast-forward must be bit-identical to drawing and
        // discarding, including across epoch-boundary reshuffles (10
        // examples, batch 4: boundaries land mid-batch)
        let d = fashion_like(10, 5);
        for skip in [0usize, 1, 2, 3, 5, 7, 12] {
            let mut drawn = BatchIter::new(&d, 4, 99);
            for _ in 0..skip {
                drawn.next_batch();
            }
            let mut skipped = BatchIter::new(&d, 4, 99);
            skipped.skip_batches(skip);
            for k in 0..4 {
                let (xa, ya) = drawn.next_batch();
                let (xb, yb) = skipped.next_batch();
                assert_eq!(ya, yb, "skip {skip}: labels diverge at batch {k}");
                assert_eq!(xa, xb, "skip {skip}: images diverge at batch {k}");
            }
        }
    }

    #[test]
    fn eval_batches_cover_all_once() {
        let d = fashion_like(10, 6);
        let bs = BatchIter::eval_batches(&d, 4);
        assert_eq!(bs.len(), 3);
        let total_valid: usize = bs.iter().map(|b| b.2).sum();
        assert_eq!(total_valid, 10);
        assert_eq!(bs[2].2, 2); // last partial
        assert_eq!(bs[2].1.len(), 4); // padded to full batch
    }
}
