//! Data-parallel training (paper Algorithm 1 across sharded workers).
//!
//! The single-process engine lives in [`crate::native::train`]; this
//! module scales it past one worker while keeping the repo's core
//! contract: the result is BIT-IDENTICAL for any shard count and
//! through any crash.  See `docs/ARCHITECTURE.md` § "Data-parallel
//! training" for the shard split rule, the pinned reduction tree, and
//! the re-sharding determinism argument.

pub mod parallel;

pub use parallel::{ParallelTrainer, ShardStats, WireStats, LEAVES};
