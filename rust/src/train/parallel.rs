//! Fault-tolerant deterministic data-parallel training.
//!
//! # Determinism model
//!
//! The unit of parallel work is NOT the shard — it is a **micro-leaf**.
//! Every global batch of `m` examples is split into [`LEAVES`] = 8
//! pinned contiguous leaves (leaf `l` = rows `l*m/8 .. (l+1)*m/8`,
//! empty leaves skipped), and the per-leaf results are combined through
//! a **fixed-association binary reduction tree**: adjacent pairs in
//! ascending leaf order, odd element carried up unchanged.  Both the
//! leaf boundaries and the tree shape are functions of `m` alone, so
//! the summed gradient — and every weight, BN stat, and
//! [`ModelState::digest`] downstream of it — is bit-identical for any
//! shard count S and any thread budget.  Shards only decide WHO
//! computes a leaf: shard `i` of `S` alive shards owns the contiguous
//! leaf run `i*n/S .. (i+1)*n/S`.  Losing a shard re-splits the SAME
//! leaf list over the survivors, so re-sharding moves time and
//! availability, never bits.
//!
//! Each leaf step is **pure**: [`TrainEngine::leaf_step`] reads a
//! shared `&ModelState` and returns gradients + leaf-local BN batch
//! stats without mutating anything.  All mutation (SGD apply in
//! backward-walk order, BN running-stat update from the tree-pooled
//! batch stats) happens in a single commit phase after EVERY leaf has
//! been collected.  Purity is what makes a retried leaf bit-exact and a
//! kill at any fault site recoverable by checkpoint resume.
//!
//! Note the `--shards` path is NOT bit-identical to the plain
//! single-process [`crate::coordinator::NativeTrainer`]: BN batch stats
//! are leaf-local (8 small batches pooled in f64, vs one global batch)
//! and the loss/gradient sums associate per-leaf.  The contract is
//! cross-S identity — `--shards 1` IS the reference for every other S.
//!
//! # Failure model
//!
//! Three injectable sites ride the `DSG_FAULTS` grammar
//! ([`crate::util::faults`]):
//!
//! * `shard.step` — a worker dies (`io`/`torn`) or stalls (`stall`)
//!   before computing a leaf.
//! * `allreduce.send` — the encoded gradient frame leaving the worker:
//!   `torn` truncates the frame mid-write and sends it anyway, `io`
//!   drops it, `stall` delays it.
//! * `allreduce.recv` — the coordinator ingesting a frame: `torn`
//!   truncates the received bytes (the decode then fails the
//!   canonical-form check and the frame is counted rejected, never
//!   summed), `io` fails the receive, `stall` sleeps then accepts.
//!
//! A round that leaves a leaf missing blames the owning shard; a blamed
//! shard is retried on the same leaves (`DSG_SHARD_RETRIES`, default 2)
//! and then declared lost.  A stalled shard trips the per-step deadline
//! `DSG_SHARD_STEP_MS` (default 30000) the same way; a late result is
//! discarded and the recomputed leaf is bit-identical by purity.  Every
//! action lands in [`crate::metrics::RecoveryCounters`].

use crate::config::RunConfig;
use crate::coordinator::init::ModelState;
use crate::coordinator::trainer::{
    run_training, run_training_opts, StepOut, TrainBackend, TrainOptions,
};
use crate::datasets::{BatchIter, Dataset};
use crate::drs::SelectionMode;
use crate::metrics::{History, MemoryMeter, OpsCounter};
use crate::native::train::{BnStat, LeafOut, TapeStorage, TrainEngine, BN_MOMENTUM};
use crate::native::{self, Mode};
use crate::runtime::Meta;
use crate::sparse::parallel::SparseKernels;
use crate::util::faults::{self, FaultKind};
use crate::zvc;
use anyhow::{bail, ensure, Context, Result};
use std::sync::mpsc;
use std::time::Duration;

/// Pinned micro-leaf count.  Fixing the leaf granularity (instead of
/// splitting by shard count) is what makes the reduction bit-identical
/// across S — see the module docs.
pub const LEAVES: usize = 8;

/// The pinned leaf boundaries of a global batch of `m` rows: leaf `l`
/// covers `l*m/L .. (l+1)*m/L` and empty leaves are skipped (a batch of
/// 4 yields 4 one-row leaves).  A pure function of `m`.
pub fn leaf_ranges(m: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for l in 0..LEAVES {
        let lo = l * m / LEAVES;
        let hi = (l + 1) * m / LEAVES;
        if hi > lo {
            out.push((lo, hi));
        }
    }
    out
}

/// Contiguous split of `n` items over `s` workers — the SAME floor rule
/// as [`leaf_ranges`], reused for the shard->leaf assignment so a
/// re-shard onto survivors is just this function at a smaller `s`.
fn split_range(n: usize, s: usize, i: usize) -> (usize, usize) {
    (i * n / s, (i + 1) * n / s)
}

/// Fixed-association pairwise reduction: adjacent pairs in ascending
/// index order, odd element carried up unchanged.  The association
/// order depends only on `xs.len()`, never on who produced the items —
/// the heart of the cross-S bit-identity argument.
fn reduce_tree<T>(mut xs: Vec<T>, mut combine: impl FnMut(T, T) -> T) -> Option<T> {
    if xs.is_empty() {
        return None;
    }
    while xs.len() > 1 {
        let mut next = Vec::with_capacity(xs.len().div_ceil(2));
        let mut it = xs.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        xs = next;
    }
    xs.pop()
}

// ---------------------------------------------------------------------
// gradient frame codec
// ---------------------------------------------------------------------

/// Magic prefix of a gradient exchange frame.
const FRAME_MAGIC: &[u8; 8] = b"DSGGRAD1";

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Wire accounting of one encoded frame's gradient payloads.
#[derive(Clone, Copy, Debug, Default)]
struct FrameMeter {
    /// gradient payload bytes actually on the wire (ZVC or raw)
    grad_wire: u64,
    /// what the same tensors would cost sent dense
    grad_dense: u64,
}

/// Encode one leaf's results as a `DSGGRAD1` frame.  Every gradient
/// tensor is ZVC-compressed ([`zvc::compress_into`]) and sent compressed
/// only when that wins (tag 1) — the masked backward makes dX/gradW
/// sparse, so it usually does.  All integers little-endian.
fn encode_frame(leaf: u32, lo: &LeafOut, comp: &mut zvc::Compressed) -> (Vec<u8>, FrameMeter) {
    let mut b = Vec::new();
    b.extend_from_slice(FRAME_MAGIC);
    put_u32(&mut b, leaf);
    put_u32(&mut b, lo.rows);
    b.extend_from_slice(&lo.loss_sum.to_le_bytes());
    put_u32(&mut b, lo.correct);
    put_u32(&mut b, lo.densities.len() as u32);
    for &(sel, tot) in &lo.densities {
        put_u64(&mut b, sel);
        put_u64(&mut b, tot);
    }
    put_u32(&mut b, lo.bn.len() as u32);
    for st in &lo.bn {
        put_u32(&mut b, st.path.len() as u32);
        b.extend_from_slice(st.path.as_bytes());
        put_u64(&mut b, st.rows);
        put_u32(&mut b, st.mean.len() as u32);
        for &v in &st.mean {
            b.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &st.var {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    put_u32(&mut b, lo.grads.len() as u32);
    let mut meter = FrameMeter::default();
    for (name, g) in &lo.grads {
        put_u32(&mut b, name.len() as u32);
        b.extend_from_slice(name.as_bytes());
        zvc::compress_into(g, comp);
        let dense = 4 * g.len();
        if comp.nbytes() + 8 < dense {
            let payload = zvc::to_bytes(comp);
            b.push(1u8);
            put_u32(&mut b, payload.len() as u32);
            meter.grad_wire += payload.len() as u64;
            b.extend_from_slice(&payload);
        } else {
            b.push(0u8);
            put_u32(&mut b, dense as u32);
            meter.grad_wire += dense as u64;
            for &v in g {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        meter.grad_dense += dense as u64;
    }
    (b, meter)
}

/// Bounds-checked little-endian cursor for [`decode_frame`].
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.i.checked_add(n)?;
        if end > self.b.len() {
            return None;
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn f32s(&mut self, n: usize) -> Option<Vec<f32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn string(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }
}

/// Total decoder for a `DSGGRAD1` frame: never panics, and rejects any
/// non-canonical buffer — truncation anywhere (including inside a ZVC
/// payload, whose own [`zvc::from_bytes`] is canonical-rejecting) and
/// trailing garbage both return `None`.  A torn frame therefore NEVER
/// decodes into a partial gradient that could be silently summed.
fn decode_frame(b: &[u8]) -> Option<(u32, LeafOut)> {
    let mut r = Rd { b, i: 0 };
    if r.take(8)? != FRAME_MAGIC {
        return None;
    }
    let leaf = r.u32()?;
    let rows = r.u32()?;
    let loss_sum = r.f64()?;
    let correct = r.u32()?;
    let nd = r.u32()? as usize;
    let mut densities = Vec::with_capacity(nd.min(1024));
    for _ in 0..nd {
        densities.push((r.u64()?, r.u64()?));
    }
    let nb = r.u32()? as usize;
    let mut bn = Vec::with_capacity(nb.min(1024));
    for _ in 0..nb {
        let path = r.string()?;
        let brows = r.u64()?;
        let n = r.u32()? as usize;
        let mean = r.f32s(n)?;
        let var = r.f32s(n)?;
        bn.push(BnStat { path, rows: brows, mean, var });
    }
    let ng = r.u32()? as usize;
    let mut grads = Vec::with_capacity(ng.min(4096));
    for _ in 0..ng {
        let name = r.string()?;
        let tag = r.u8()?;
        let plen = r.u32()? as usize;
        let payload = r.take(plen)?;
        let g = match tag {
            0 => {
                if plen % 4 != 0 {
                    return None;
                }
                payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
            1 => {
                let c = zvc::from_bytes(payload)?;
                let mut out = Vec::new();
                zvc::decompress_into(&c, &mut out);
                out
            }
            _ => return None,
        };
        grads.push((name, g));
    }
    if r.i != b.len() {
        return None; // trailing bytes: not a canonical frame
    }
    Some((leaf, LeafOut { rows, loss_sum, correct, densities, bn, grads }))
}

// ---------------------------------------------------------------------
// the trainer
// ---------------------------------------------------------------------

/// Per-shard lifetime statistics (`dsg train --shards` prints these).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    /// leaves this shard computed and the coordinator accepted
    pub leaves_done: u64,
    /// rounds this shard was blamed for (failed / torn / timed out)
    pub retries: u64,
    /// still participating?
    pub alive: bool,
}

/// Gradient-exchange wire accounting (feeds `BENCH_train.json`).
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// total encoded frame bytes received by the coordinator
    pub frame_bytes: u64,
    /// gradient payload bytes on the wire (ZVC where it wins)
    pub grad_wire_bytes: u64,
    /// dense-equivalent bytes of the same gradient tensors
    pub grad_dense_bytes: u64,
}

impl WireStats {
    /// Dense / wire compression ratio of the gradient exchange.
    pub fn ratio(&self) -> f64 {
        if self.grad_wire_bytes == 0 {
            return 1.0;
        }
        self.grad_dense_bytes as f64 / self.grad_wire_bytes as f64
    }
}

/// What one worker sends back per leaf: the encoded frame plus its wire
/// accounting, or the failure text.
type LeafMsg = (usize, usize, std::result::Result<(Vec<u8>, FrameMeter), String>);

/// The data-parallel training coordinator: S sharded workers over the
/// pinned micro-leaf split, fixed-tree all-reduce, straggler recovery.
/// Implements [`TrainBackend`], so the whole outer loop (batching,
/// schedules, checkpoints, `--resume auto`) is shared with the
/// single-process trainers.
pub struct ParallelTrainer {
    pub meta: Meta,
    pub state: ModelState,
    /// one engine per shard (index = shard id; dead shards keep theirs)
    engines: Vec<TrainEngine>,
    mode: Mode,
    shards: usize,
    // engine settings recorded so `restore` can rebuild
    threads: usize,
    tape: TapeStorage,
    kernels: SparseKernels,
    selection: SelectionMode,
    /// shard participation: a lost shard stays false until `restore`
    alive: Vec<bool>,
    stats: Vec<ShardStats>,
    reshard_events: u64,
    wire: WireStats,
    /// per-step deadline before missing leaves blame their shard
    deadline: Duration,
    /// per-step blamed rounds a shard survives before it is lost
    max_retries: u64,
    pub steps_done: usize,
    pub history: History,
}

impl ParallelTrainer {
    /// Initialize from a meta: weights from `ModelState::init`, initial
    /// Wp from the host projection, `shards` workers.
    pub fn new(meta: Meta, seed: u64, shards: usize) -> Result<ParallelTrainer> {
        let mut state = ModelState::init(&meta, seed);
        native::project_host(&meta, &mut state)?;
        Self::with_state(meta, state, shards)
    }

    /// Resume from an existing state (checkpoint load); the restored Wp
    /// is trusted as-is, exactly like [`crate::coordinator::NativeTrainer`].
    pub fn with_state(meta: Meta, state: ModelState, shards: usize) -> Result<ParallelTrainer> {
        ensure!(shards >= 1, "--shards must be >= 1");
        ensure!(shards <= LEAVES, "--shards {shards} exceeds the {LEAVES} micro-leaves");
        let threads = crate::sparse::parallel::n_threads();
        let deadline = Duration::from_millis(env_u64("DSG_SHARD_STEP_MS", 30_000));
        let max_retries = env_u64("DSG_SHARD_RETRIES", 2);
        let tape = TapeStorage::default();
        let kernels = SparseKernels::default();
        let selection = SelectionMode::default();
        let engines = build_engines(&meta, &state, shards, threads, tape, kernels, selection)?;
        let mode = engines[0].default_mode();
        Ok(ParallelTrainer {
            meta,
            state,
            engines,
            mode,
            shards,
            threads,
            tape,
            kernels,
            selection,
            alive: vec![true; shards],
            stats: vec![ShardStats { alive: true, ..ShardStats::default() }; shards],
            reshard_events: 0,
            wire: WireStats::default(),
            deadline,
            max_retries,
            steps_done: 0,
            history: History::default(),
        })
    }

    /// Cap the TOTAL intra-op thread budget; each shard's engine gets an
    /// equal slice (bit-exact at any budget — the kernels are).
    pub fn with_threads(mut self, threads: usize) -> Result<ParallelTrainer> {
        self.threads = threads.max(1);
        self.engines = build_engines(
            &self.meta, &self.state, self.shards, self.threads, self.tape, self.kernels,
            self.selection,
        )?;
        Ok(self)
    }

    /// Select the training-tape storage (`--tape zvc`), per shard.
    pub fn with_tape(mut self, tape: TapeStorage) -> ParallelTrainer {
        self.tape = tape;
        self.engines = self.engines.into_iter().map(|e| e.with_tape(tape)).collect();
        self
    }

    /// Select the sparse kernel family (see [`crate::coordinator::NativeTrainer`]).
    pub fn with_kernels(mut self, kernels: SparseKernels) -> ParallelTrainer {
        self.kernels = kernels;
        self.engines = self.engines.into_iter().map(|e| e.with_kernels(kernels)).collect();
        self
    }

    /// Select the DRS mask-selection mode (`--selection`).
    pub fn with_selection(mut self, selection: SelectionMode) -> ParallelTrainer {
        self.selection = selection;
        self.engines = self.engines.into_iter().map(|e| e.with_selection(selection)).collect();
        self
    }

    /// Force dense (keep-all mask) execution — the convergence baseline.
    pub fn with_mode(mut self, mode: Mode) -> ParallelTrainer {
        self.mode = mode;
        self
    }

    /// Override the per-step straggler deadline (tests; the CLI reads
    /// `DSG_SHARD_STEP_MS` at construction).
    pub fn with_deadline(mut self, deadline: Duration) -> ParallelTrainer {
        self.deadline = deadline;
        self
    }

    /// Override the blamed-rounds-per-step budget before a shard is
    /// declared lost (tests; the CLI reads `DSG_SHARD_RETRIES`).
    pub fn with_max_retries(mut self, retries: u64) -> ParallelTrainer {
        self.max_retries = retries;
        self
    }

    /// Per-shard lifetime statistics.
    pub fn shard_stats(&self) -> &[ShardStats] {
        &self.stats
    }

    /// Re-sharding events (a shard death that re-split the leaf list).
    pub fn reshards(&self) -> u64 {
        self.reshard_events
    }

    /// Gradient-exchange wire accounting since construction.
    pub fn wire_stats(&self) -> WireStats {
        self.wire
    }

    /// Shard count this trainer was built with.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Measured tape memory of shard 0's most recent leaf step.
    pub fn tape_memory(&self) -> &MemoryMeter {
        self.engines[0].memory()
    }

    /// Measured realized vs dense multiply-adds of shard 0's most
    /// recent leaf step.
    pub fn ops(&self) -> &OpsCounter {
        self.engines[0].ops()
    }

    /// Host-side Wp refresh (the paper's amortized projection).
    pub fn refresh_projection(&mut self) -> Result<()> {
        native::project_host(&self.meta, &mut self.state)
    }

    /// One data-parallel training step: fan the pinned leaves out over
    /// the alive shards, collect every leaf (retrying / re-sharding on
    /// failure), reduce through the fixed tree, then commit — SGD in
    /// backward-walk order, BN running stats from the f64-pooled batch
    /// stats.  Nothing mutates until all leaves are in.
    pub fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        let m = y.len();
        ensure!(m > 0, "empty batch");
        let d = self.meta.input_elems();
        ensure!(x.len() == m * d, "x has {} elems, expected {}", x.len(), m * d);
        let leaves = leaf_ranges(m);
        let n = leaves.len();
        let mut slots: Vec<Option<LeafOut>> = (0..n).map(|_| None).collect();
        // effective fault plan captured once so scope-spawned workers
        // share the plan AND its hit counters
        let fh = faults::capture();
        // blamed rounds per shard, this step only
        let mut step_retries = vec![0u64; self.shards];
        loop {
            let missing = slots.iter().filter(|s| s.is_none()).count();
            if missing == 0 {
                break;
            }
            let alive: Vec<usize> = (0..self.shards).filter(|&s| self.alive[s]).collect();
            if alive.is_empty() {
                bail!(
                    "all {} shards lost at step {} — resume from the last checkpoint",
                    self.shards,
                    self.steps_done
                );
            }
            let sa = alive.len();
            // static contiguous assignment of the FULL leaf list over
            // the alive shards (ownership is deterministic — timeouts
            // know whom to blame); each worker computes assigned-and-
            // still-missing leaves only
            let mut owner = vec![usize::MAX; n];
            let mut work: Vec<(usize, Vec<usize>)> = Vec::new();
            let mut expected = 0usize;
            for (wi, &s) in alive.iter().enumerate() {
                let (lo, hi) = split_range(n, sa, wi);
                for li in lo..hi {
                    owner[li] = s;
                }
                let mine: Vec<usize> = (lo..hi).filter(|li| slots[*li].is_none()).collect();
                if !mine.is_empty() {
                    expected += mine.len();
                    work.push((s, mine));
                }
            }
            let mut failed = vec![false; self.shards];
            let mut timed_out = false;
            let mut leaves_done = vec![0u64; self.shards];
            let mut wire = WireStats::default();
            {
                let mut engs: Vec<Option<&mut TrainEngine>> =
                    self.engines.iter_mut().map(Some).collect();
                let state = &self.state;
                let mode = self.mode;
                let deadline = self.deadline;
                let leaves = &leaves;
                let slots = &mut slots;
                let (tx, rx) = mpsc::channel::<LeafMsg>();
                std::thread::scope(|sc| {
                    for (s, mine) in work {
                        let eng = engs[s].take().expect("one worker per shard");
                        let tx = tx.clone();
                        let fh = fh.clone();
                        sc.spawn(move || {
                            // re-arm the captured fault plan in this
                            // worker thread (shared hit counters)
                            faults::scoped(&fh, || {
                                let mut comp = zvc::Compressed::new();
                                for li in mine {
                                    let (lo, hi) = leaves[li];
                                    let res = worker_leaf(
                                        eng, state, x, y, d, lo, hi, li, gamma, m, mode, &mut comp,
                                    );
                                    // the receiver may have moved on
                                    // (deadline): a failed send is fine,
                                    // the leaf will be recomputed
                                    let _ = tx.send((li, s, res));
                                }
                            });
                        });
                    }
                    drop(tx);
                    let mut got = 0usize;
                    while got < expected {
                        let (li, s, res) = match rx.recv_timeout(deadline) {
                            Ok(msg) => msg,
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                timed_out = true;
                                break;
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        };
                        got += 1;
                        let mut bytes = match res {
                            Ok((bytes, fm)) => {
                                wire.grad_wire_bytes += fm.grad_wire;
                                wire.grad_dense_bytes += fm.grad_dense;
                                bytes
                            }
                            Err(e) => {
                                crate::warn!("shard {s} leaf {li} failed: {e}");
                                failed[s] = true;
                                continue;
                            }
                        };
                        wire.frame_bytes += bytes.len() as u64;
                        // fault site: the coordinator ingesting a frame
                        match faults::check("allreduce.recv") {
                            Some(FaultKind::Stall) => faults::absorb_stall(),
                            Some(FaultKind::Torn) => {
                                let half = bytes.len() / 2;
                                bytes.truncate(half);
                            }
                            Some(_) => {
                                crate::warn!("shard {s} leaf {li}: injected recv failure");
                                failed[s] = true;
                                continue;
                            }
                            None => {}
                        }
                        match decode_frame(&bytes) {
                            Some((fl, out)) if fl as usize == li => {
                                // idempotent slot: first valid frame
                                // wins, duplicates are discarded
                                if slots[li].is_none() {
                                    slots[li] = Some(out);
                                    leaves_done[s] += 1;
                                }
                            }
                            _ => {
                                // torn / corrupt frame: rejected by the
                                // canonical-form check, NEVER summed
                                crate::metrics::recovery().on_frame_rejected();
                                crate::warn!("shard {s} leaf {li}: rejected non-canonical frame");
                                failed[s] = true;
                            }
                        }
                    }
                    // a timed-out round stops listening; stalled workers
                    // finish their bounded sleep and their late sends go
                    // nowhere — the leaves are recomputed, bit-exact
                });
            }
            self.wire.frame_bytes += wire.frame_bytes;
            self.wire.grad_wire_bytes += wire.grad_wire_bytes;
            self.wire.grad_dense_bytes += wire.grad_dense_bytes;
            let mut any_lost = false;
            for s in 0..self.shards {
                self.stats[s].leaves_done += leaves_done[s];
                let owns_missing = (0..n).any(|li| slots[li].is_none() && owner[li] == s);
                if !(failed[s] || (timed_out && owns_missing)) {
                    continue;
                }
                step_retries[s] += 1;
                self.stats[s].retries += 1;
                crate::metrics::recovery().on_shard_retry();
                if step_retries[s] > self.max_retries {
                    self.alive[s] = false;
                    self.stats[s].alive = false;
                    any_lost = true;
                    crate::metrics::recovery().on_shard_lost();
                    crate::warn!(
                        "shard {s} lost at step {} after {} blamed rounds",
                        self.steps_done,
                        step_retries[s]
                    );
                }
            }
            if any_lost {
                // the SAME leaf list re-splits over the survivors next
                // round — ownership moves, bits don't
                self.reshard_events += 1;
                crate::metrics::recovery().on_reshard();
            }
        }
        let outs: Vec<LeafOut> = slots.into_iter().map(|s| s.expect("all leaves collected")).collect();
        let out = self.commit(outs, m, lr)?;
        self.steps_done += 1;
        Ok(out)
    }

    /// The commit phase: reduce every collected leaf through the pinned
    /// tree and apply ALL state mutation.  Runs only when every leaf is
    /// in — a crash before this point loses no state, a crash after it
    /// is covered by the checkpoint of the completed step.
    fn commit(&mut self, outs: Vec<LeafOut>, m: usize, lr: f32) -> Result<StepOut> {
        let rows: u64 = outs.iter().map(|o| o.rows as u64).sum();
        ensure!(rows == m as u64, "leaves cover {rows} rows, batch has {m}");
        // scalar sums: loss in f64 through the tree, correct is integer
        // (associative anyway, reduced the same way for uniformity)
        let loss_sum =
            reduce_tree(outs.iter().map(|o| o.loss_sum).collect(), |a, b| a + b).unwrap_or(0.0);
        let correct =
            reduce_tree(outs.iter().map(|o| o.correct as u64).collect(), |a, b| a + b)
                .unwrap_or(0);
        // per-layer (selected, total) counts — integers, exactly the
        // global mask census regardless of leaf boundaries
        let nd = outs[0].densities.len();
        ensure!(
            outs.iter().all(|o| o.densities.len() == nd),
            "leaves disagree on layer count"
        );
        let mut densities = Vec::with_capacity(nd);
        for k in 0..nd {
            let sel = reduce_tree(outs.iter().map(|o| o.densities[k].0).collect(), |a, b| a + b)
                .unwrap_or(0);
            let tot = reduce_tree(outs.iter().map(|o| o.densities[k].1).collect(), |a, b| a + b)
                .unwrap_or(0);
            densities.push(sel as f32 / tot.max(1) as f32);
        }
        // BN: pool the leaf-local batch stats through the tree in f64
        // (leaf contributes weight w = rows, w*mean, w*(var + mean^2)),
        // then one running update — the shard-count-invariant twin of
        // the single-process per-batch update
        let nb = outs[0].bn.len();
        ensure!(outs.iter().all(|o| o.bn.len() == nb), "leaves disagree on BN entry count");
        for k in 0..nb {
            let path = outs[0].bn[k].path.clone();
            let len = outs[0].bn[k].mean.len();
            for o in &outs {
                ensure!(
                    o.bn[k].path == path && o.bn[k].mean.len() == len && o.bn[k].var.len() == len,
                    "leaves disagree on BN entry {k}"
                );
            }
            let pooled = reduce_tree(
                outs.iter()
                    .map(|o| {
                        let st = &o.bn[k];
                        let w = st.rows as f64;
                        let s1: Vec<f64> = st.mean.iter().map(|&mu| w * mu as f64).collect();
                        let s2: Vec<f64> = st
                            .mean
                            .iter()
                            .zip(&st.var)
                            .map(|(&mu, &va)| w * (va as f64 + (mu as f64) * (mu as f64)))
                            .collect();
                        (w, s1, s2)
                    })
                    .collect(),
                |(wa, s1a, s2a), (wb, s1b, s2b)| {
                    (
                        wa + wb,
                        s1a.iter().zip(&s1b).map(|(a, b)| a + b).collect(),
                        s2a.iter().zip(&s2b).map(|(a, b)| a + b).collect(),
                    )
                },
            )
            .expect("at least one leaf");
            let (w, s1, s2) = pooled;
            let mean: Vec<f32> = s1.iter().map(|&v| (v / w) as f32).collect();
            let var: Vec<f32> = s1
                .iter()
                .zip(&s2)
                .map(|(&a, &b)| {
                    let mu = a / w;
                    (b / w - mu * mu) as f32
                })
                .collect();
            for (leaf_name, batch) in [
                (format!("bn_state.{path}.mean"), &mean),
                (format!("bn_state.{path}.var"), &var),
            ] {
                let i = self.engines[0].leaf(&leaf_name)?;
                let run = self.state.state[i].as_f32_mut()?;
                ensure!(run.len() == batch.len(), "{leaf_name}: stat len mismatch");
                for (r, &b) in run.iter_mut().zip(batch) {
                    *r = BN_MOMENTUM * *r + (1.0 - BN_MOMENTUM) * b;
                }
            }
        }
        // gradients: pinned tree per tensor (leaf dlogits already carry
        // 1/m_global, so the tree sum IS the global mean-loss gradient),
        // then SGD in the backward-walk order every leaf shares
        let names: Vec<&str> = outs[0].grads.iter().map(|(nm, _)| nm.as_str()).collect();
        for o in &outs {
            ensure!(
                o.grads.len() == names.len()
                    && o.grads.iter().zip(&names).all(|((nm, _), want)| nm == want),
                "leaves disagree on gradient tensor order"
            );
        }
        for gi in 0..names.len() {
            let glen = outs[0].grads[gi].1.len();
            ensure!(
                outs.iter().all(|o| o.grads[gi].1.len() == glen),
                "{}: leaves disagree on gradient length",
                names[gi]
            );
            let g = reduce_tree(
                outs.iter().map(|o| o.grads[gi].1.clone()).collect(),
                |mut a: Vec<f32>, b: Vec<f32>| {
                    for (av, bv) in a.iter_mut().zip(&b) {
                        *av += bv;
                    }
                    a
                },
            )
            .expect("at least one leaf");
            self.engines[0].sgd_update(&mut self.state, names[gi], &g, lr)?;
        }
        Ok(StepOut {
            loss: (loss_sum / m as f64) as f32,
            acc: correct as f32 / m as f32,
            densities,
        })
    }

    /// Forward one batch in eval mode (running-stat BN); returns logits.
    pub fn forward(&mut self, x: &[f32], m: usize, gamma: f32) -> Result<Vec<f32>> {
        self.engines[0].forward_eval(&self.state, x, m, gamma, self.mode)
    }

    /// Evaluate accuracy over a dataset (padded final batch handled).
    pub fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32> {
        let batch = self.meta.batch;
        let c = self.meta.classes;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (xs, ys, valid) in BatchIter::eval_batches(data, batch) {
            let logits = self.forward(&xs, batch, gamma)?;
            for (i, &yv) in ys.iter().enumerate().take(valid) {
                if crate::serve::argmax(&logits[i * c..(i + 1) * c]) == yv as usize {
                    correct += 1;
                }
            }
            total += valid;
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// The full training loop per `cfg` (see
    /// [`crate::coordinator::trainer::run_training`]).
    pub fn train(&mut self, cfg: &RunConfig, train: &Dataset, test: &Dataset) -> Result<f32> {
        run_training(self, cfg, train, test)
    }

    /// [`Self::train`] with a checkpoint/resume policy.
    pub fn train_opts(
        &mut self,
        cfg: &RunConfig,
        train: &Dataset,
        test: &Dataset,
        opts: &TrainOptions,
    ) -> Result<f32> {
        run_training_opts(self, cfg, train, test, opts)
    }
}

/// One worker's unit of work: fault gate, pure leaf step, frame encode,
/// send-side fault gate.  Returns the wire-ready frame (possibly torn —
/// the coordinator's canonical-form check owns rejecting it).
#[allow(clippy::too_many_arguments)]
fn worker_leaf(
    eng: &mut TrainEngine,
    state: &ModelState,
    x: &[f32],
    y: &[i32],
    d: usize,
    lo: usize,
    hi: usize,
    li: usize,
    gamma: f32,
    denom: usize,
    mode: Mode,
    comp: &mut zvc::Compressed,
) -> std::result::Result<(Vec<u8>, FrameMeter), String> {
    // fault site: the shard dying (io/torn) or stalling before its work
    match faults::check("shard.step") {
        Some(FaultKind::Stall) => faults::absorb_stall(),
        Some(_) => return Err(format!("injected fault at shard.step (leaf {li})")),
        None => {}
    }
    let out = eng
        .leaf_step(state, &x[lo * d..hi * d], &y[lo..hi], gamma, denom, mode)
        .map_err(|e| format!("{e:#}"))?;
    let (mut frame, meter) = encode_frame(li as u32, &out, comp);
    // fault site: the gradient frame leaving the shard — `torn` sends a
    // truncated frame (receiver must reject it), `io` loses it
    match faults::check("allreduce.send") {
        Some(FaultKind::Stall) => faults::absorb_stall(),
        Some(FaultKind::Torn) => {
            let half = frame.len() / 2;
            frame.truncate(half);
        }
        Some(_) => return Err(format!("injected fault at allreduce.send (leaf {li})")),
        None => {}
    }
    Ok((frame, meter))
}

fn build_engines(
    meta: &Meta,
    state: &ModelState,
    shards: usize,
    threads: usize,
    tape: TapeStorage,
    kernels: SparseKernels,
    selection: SelectionMode,
) -> Result<Vec<TrainEngine>> {
    let per = (threads / shards).max(1);
    (0..shards)
        .map(|_| {
            Ok(TrainEngine::new(meta, state)?
                .with_threads(per)
                .with_tape(tape)
                .with_kernels(kernels)
                .with_selection(selection))
        })
        .collect()
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

impl TrainBackend for ParallelTrainer {
    fn name(&self) -> &str {
        &self.meta.name
    }

    fn batch_size(&self) -> usize {
        self.meta.batch
    }

    fn refresh_projection(&mut self) -> Result<()> {
        ParallelTrainer::refresh_projection(self)
    }

    fn step(&mut self, x: &[f32], y: &[i32], gamma: f32, lr: f32) -> Result<StepOut> {
        ParallelTrainer::step(self, x, y, gamma, lr)
            .with_context(|| format!("data-parallel step at {} shards", self.shards))
    }

    fn evaluate(&mut self, data: &Dataset, gamma: f32) -> Result<f32> {
        ParallelTrainer::evaluate(self, data, gamma)
    }

    fn history_mut(&mut self) -> &mut History {
        &mut self.history
    }

    fn state(&self) -> &ModelState {
        &self.state
    }

    fn steps_done(&self) -> usize {
        self.steps_done
    }

    fn restore(&mut self, state: ModelState, steps_done: usize) -> Result<()> {
        // rebuild every shard engine against the restored state; a
        // fresh process has no memory of lost shards, so all revive —
        // determinism is unaffected (shards move time, not bits)
        self.engines = build_engines(
            &self.meta, &state, self.shards, self.threads, self.tape, self.kernels,
            self.selection,
        )?;
        self.state = state;
        self.steps_done = steps_done;
        self.alive = vec![true; self.shards];
        for st in &mut self.stats {
            st.alive = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_ranges_cover_and_pin() {
        for m in [1usize, 3, 4, 7, 8, 9, 32, 33, 100] {
            let lr = leaf_ranges(m);
            assert!(!lr.is_empty());
            assert!(lr.len() <= LEAVES);
            assert_eq!(lr[0].0, 0);
            assert_eq!(lr.last().unwrap().1, m);
            for w in lr.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert!(w[0].1 > w[0].0, "nonempty");
            }
        }
        // batch 4: four one-row leaves
        assert_eq!(leaf_ranges(4), vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
    }

    #[test]
    fn reduce_tree_association_is_pinned() {
        // the association order is a pure function of the item count
        let items: Vec<String> = ["a", "b", "c", "d", "e"].iter().map(|s| s.to_string()).collect();
        let out = reduce_tree(items, |a, b| format!("({a}+{b})")).unwrap();
        assert_eq!(out, "(((a+b)+(c+d))+e)");
        assert_eq!(reduce_tree(Vec::<i32>::new(), |a, b| a + b), None);
        assert_eq!(reduce_tree(vec![7], |a, b| a + b), Some(7));
    }

    fn sample_leaf_out() -> LeafOut {
        LeafOut {
            rows: 3,
            loss_sum: 1.25,
            correct: 2,
            densities: vec![(5, 10), (0, 4)],
            bn: vec![BnStat {
                path: "0".into(),
                rows: 3,
                mean: vec![0.5, -1.0],
                var: vec![0.25, 2.0],
            }],
            grads: vec![
                ("params.0.w".into(), vec![0.0, 1.5, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0]),
                ("params.1.b".into(), vec![1.0, 2.0]),
            ],
        }
    }

    #[test]
    fn frame_roundtrip_is_exact() {
        let lo = sample_leaf_out();
        let mut comp = zvc::Compressed::new();
        let (bytes, meter) = encode_frame(6, &lo, &mut comp);
        assert!(meter.grad_dense >= meter.grad_wire, "compress-if-smaller never grows");
        let (leaf, back) = decode_frame(&bytes).expect("roundtrip");
        assert_eq!(leaf, 6);
        assert_eq!(back.rows, lo.rows);
        assert_eq!(back.loss_sum.to_bits(), lo.loss_sum.to_bits());
        assert_eq!(back.correct, lo.correct);
        assert_eq!(back.densities, lo.densities);
        assert_eq!(back.bn.len(), 1);
        assert_eq!(back.bn[0].path, "0");
        assert_eq!(back.bn[0].rows, 3);
        assert_eq!(back.bn[0].mean, lo.bn[0].mean);
        assert_eq!(back.bn[0].var, lo.bn[0].var);
        assert_eq!(back.grads, lo.grads);
    }

    #[test]
    fn every_torn_prefix_is_rejected() {
        // the decoder is total AND canonical: no strict prefix of a
        // valid frame decodes — a torn frame can never be summed
        let lo = sample_leaf_out();
        let mut comp = zvc::Compressed::new();
        let (bytes, _) = encode_frame(2, &lo, &mut comp);
        for cut in 0..bytes.len() {
            assert!(
                decode_frame(&bytes[..cut]).is_none(),
                "torn frame of {cut}/{} bytes decoded",
                bytes.len()
            );
        }
        // trailing garbage is equally non-canonical
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_frame(&long).is_none());
        // and a wrong magic dies immediately
        let mut wrong = bytes;
        wrong[0] ^= 0xff;
        assert!(decode_frame(&wrong).is_none());
    }

    #[test]
    fn shard_assignment_re_splits_deterministically() {
        // losing a shard re-splits the SAME leaf list: the union of the
        // survivor ranges is always exactly 0..n, in order
        for n in 1..=LEAVES {
            for s in 1..=n {
                let mut covered = Vec::new();
                for i in 0..s {
                    let (lo, hi) = split_range(n, s, i);
                    covered.extend(lo..hi);
                }
                assert_eq!(covered, (0..n).collect::<Vec<_>>(), "n={n} s={s}");
            }
        }
    }
}
