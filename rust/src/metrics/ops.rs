//! Measured operation counting — the paper's Fig 9 reduction ratios as
//! numbers we record, not just model.
//!
//! The compound kernels ([`crate::sparse::parallel`]) return the
//! multiply-adds they actually executed (the dispatch decides per layer
//! and per row between dense sweeps and indexed accumulation, and the
//! count follows the decision).  This module aggregates those counts
//! against the dense-equivalent baseline `m * d * n`, per named layer,
//! so `dsg train` / `dsg serve` summaries and the hotpath bench can
//! report realized-ops reductions à la Fig 9.
//!
//! Two shapes:
//!   * [`OpsCounter`] — per-layer named records for engines that walk a
//!     topology (native forward / backward).
//!   * [`OpsMeter`]   — two shared atomics for concurrent paths (serve
//!     workers) where per-layer attribution isn't worth a lock.

use std::sync::atomic::{AtomicU64, Ordering};

/// Realized vs dense-equivalent multiply-adds of one layer.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerOps {
    pub name: String,
    /// Multiply-adds the kernels actually executed.
    pub realized: u64,
    /// What a dense GEMM of the same shape costs (m * d * n).
    pub dense: u64,
}

impl LayerOps {
    /// Dense / realized — the Fig 9 reduction ratio (1.0 when nothing
    /// was realized, so empty layers don't divide by zero).
    pub fn reduction(&self) -> f64 {
        if self.realized == 0 {
            return 1.0;
        }
        self.dense as f64 / self.realized as f64
    }
}

/// Accumulating per-layer operation counts (forward and/or backward),
/// merged by layer name in first-seen order.
#[derive(Clone, Debug, Default)]
pub struct OpsCounter {
    layers: Vec<LayerOps>,
}

impl OpsCounter {
    pub fn new() -> OpsCounter {
        OpsCounter::default()
    }

    /// Forget everything (capacity kept).
    pub fn reset(&mut self) {
        self.layers.clear();
    }

    /// Add one layer's counts (accumulates if the name was seen before,
    /// so forward + backward of the same layer merge into one record).
    pub fn record(&mut self, name: &str, realized: u64, dense: u64) {
        if let Some(l) = self.layers.iter_mut().find(|l| l.name == name) {
            l.realized += realized;
            l.dense += dense;
        } else {
            self.layers.push(LayerOps { name: name.to_string(), realized, dense });
        }
    }

    /// Per-layer records in first-seen (topology) order.
    pub fn layers(&self) -> &[LayerOps] {
        &self.layers
    }

    pub fn total_realized(&self) -> u64 {
        self.layers.iter().map(|l| l.realized).sum()
    }

    pub fn total_dense(&self) -> u64 {
        self.layers.iter().map(|l| l.dense).sum()
    }

    /// Overall dense / realized reduction (1.0 for an empty counter).
    pub fn reduction(&self) -> f64 {
        let r = self.total_realized();
        if r == 0 {
            return 1.0;
        }
        self.total_dense() as f64 / r as f64
    }

    /// One-line human summary for CLI reports.
    pub fn summary(&self) -> String {
        format!(
            "{} realized vs {} dense-equivalent madds -> {:.2}x reduction",
            human_madds(self.total_realized()),
            human_madds(self.total_dense()),
            self.reduction()
        )
    }
}

/// Lock-free realized/dense aggregate for concurrent paths (relaxed
/// adds: totals are exact, interleaving order is irrelevant for sums).
#[derive(Debug, Default)]
pub struct OpsMeter {
    realized: AtomicU64,
    dense: AtomicU64,
}

impl OpsMeter {
    pub fn new() -> OpsMeter {
        OpsMeter::default()
    }

    pub fn add(&self, realized: u64, dense: u64) {
        self.realized.fetch_add(realized, Ordering::Relaxed);
        self.dense.fetch_add(dense, Ordering::Relaxed);
    }

    pub fn realized(&self) -> u64 {
        self.realized.load(Ordering::Relaxed)
    }

    pub fn dense(&self) -> u64 {
        self.dense.load(Ordering::Relaxed)
    }

    pub fn reduction(&self) -> f64 {
        let r = self.realized();
        if r == 0 {
            return 1.0;
        }
        self.dense() as f64 / r as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} realized vs {} dense-equivalent madds -> {:.2}x reduction",
            human_madds(self.realized()),
            human_madds(self.dense()),
            self.reduction()
        )
    }
}

/// Format a multiply-add count with engineering units.
pub fn human_madds(n: u64) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.2}G", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.2}k", f / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_merges_by_name() {
        let mut c = OpsCounter::new();
        c.record("conv1", 100, 400);
        c.record("conv2", 50, 100);
        c.record("conv1", 25, 100); // backward of conv1 merges
        assert_eq!(c.layers().len(), 2);
        assert_eq!(c.layers()[0].realized, 125);
        assert_eq!(c.layers()[0].dense, 500);
        assert_eq!(c.total_realized(), 175);
        assert_eq!(c.total_dense(), 600);
        assert!((c.reduction() - 600.0 / 175.0).abs() < 1e-12);
        c.reset();
        assert_eq!(c.reduction(), 1.0);
        assert!(c.layers().is_empty());
    }

    #[test]
    fn layer_reduction_and_empty_cases() {
        let l = LayerOps { name: "x".into(), realized: 250, dense: 1000 };
        assert!((l.reduction() - 4.0).abs() < 1e-12);
        let z = LayerOps { name: "z".into(), realized: 0, dense: 0 };
        assert_eq!(z.reduction(), 1.0);
    }

    #[test]
    fn meter_accumulates_concurrently() {
        let m = std::sync::Arc::new(OpsMeter::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.add(3, 12);
                    }
                });
            }
        });
        assert_eq!(m.realized(), 1200);
        assert_eq!(m.dense(), 4800);
        assert!((m.reduction() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn madds_formatting() {
        assert_eq!(human_madds(12), "12");
        assert_eq!(human_madds(1500), "1.50k");
        assert_eq!(human_madds(2_000_000), "2.00M");
        assert_eq!(human_madds(3_500_000_000), "3.50G");
    }
}
