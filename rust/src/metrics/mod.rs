//! Run metrics: step histories, summary statistics, latency histograms,
//! tape-memory and realized-ops meters, per-shard serving counters,
//! CSV/JSONL writers.

pub mod counters;
pub mod hist;
pub mod memory;
pub mod ops;

pub use counters::{recovery, RecoveryCounters, RecoverySnapshot, ShardCounters, ShardSnapshot};
pub use hist::LatencyHistogram;
pub use memory::{MemoryMeter, TapeAlloc};
pub use ops::{LayerOps, OpsCounter, OpsMeter};

use std::io::Write;

/// One recorded training step.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub densities: Vec<f32>,
    pub secs: f64,
}

/// Accumulating history of a training run.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<(usize, f32)>, // (step, eval accuracy)
}

impl History {
    pub fn push(&mut self, rec: StepRecord) {
        self.steps.push(rec);
    }

    pub fn push_eval(&mut self, step: usize, acc: f32) {
        self.evals.push((step, acc));
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    pub fn best_eval(&self) -> Option<f32> {
        self.evals.iter().map(|&(_, a)| a).fold(None, |m, a| {
            Some(m.map_or(a, |m: f32| m.max(a)))
        })
    }

    /// Mean loss over the trailing `n` steps (smoothed curve point).
    pub fn smoothed_loss(&self, n: usize) -> Option<f32> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        Some(tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Mean mask density over the trailing n steps, per layer.
    pub fn mean_densities(&self, n: usize) -> Vec<f32> {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return Vec::new();
        }
        let nl = tail[0].densities.len();
        let mut out = vec![0.0f32; nl];
        for s in tail {
            for (o, d) in out.iter_mut().zip(&s.densities) {
                *o += d;
            }
        }
        for o in out.iter_mut() {
            *o /= tail.len() as f32;
        }
        out
    }

    pub fn total_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.secs).sum()
    }

    /// Write the step history as CSV.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,acc,secs,mean_density")?;
        for s in &self.steps {
            let md = if s.densities.is_empty() {
                1.0
            } else {
                s.densities.iter().sum::<f32>() / s.densities.len() as f32
            };
            writeln!(f, "{},{},{},{},{}", s.step, s.loss, s.acc, s.secs, md)?;
        }
        Ok(())
    }
}

/// Basic summary stats over a slice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize of empty slice");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: sorted[n / 2],
    }
}

/// Format seconds with sensible units.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepRecord {
        StepRecord { step, loss, acc: 0.5, densities: vec![0.4, 0.6], secs: 0.01 }
    }

    #[test]
    fn history_accumulates() {
        let mut h = History::default();
        for i in 0..10 {
            h.push(rec(i, 10.0 - i as f32));
        }
        assert_eq!(h.last_loss(), Some(1.0));
        assert!((h.smoothed_loss(4).unwrap() - 2.5).abs() < 1e-5);
        assert_eq!(h.mean_densities(5), vec![0.4, 0.6]);
        assert!((h.total_secs() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn eval_tracking() {
        let mut h = History::default();
        h.push_eval(10, 0.4);
        h.push_eval(20, 0.7);
        h.push_eval(30, 0.6);
        assert_eq!(h.best_eval(), Some(0.7));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut h = History::default();
        h.push(rec(0, 2.0));
        h.push(rec(1, 1.5));
        let dir = std::env::temp_dir().join("dsg_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("h.csv");
        h.write_csv(&p).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert_eq!(txt.lines().count(), 3);
        assert!(txt.starts_with("step,loss"));
    }

    #[test]
    fn summary_stats() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 22.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        summarize(&[]);
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(0.0000005), "0.5us");
        assert_eq!(fmt_secs(0.05), "50.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
    }
}
