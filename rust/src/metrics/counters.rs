//! Lock-free per-shard serving counters.
//!
//! Each shard of the sharded serving engine ([`crate::serve::shard`])
//! owns one [`ShardCounters`]: the dispatcher bumps it on enqueue and
//! rejection, workers bump it on take/steal.  Everything is a relaxed
//! atomic — the counters are observability, never control flow, so a
//! stale read is fine and the hot path pays one `fetch_add` per block
//! (blocks, not requests: a block is `max_batch` requests).
//!
//! [`ShardSnapshot`] is the plain-data copy taken at report time, used
//! by `dsg serve` summaries and `BENCH_serve.json`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters for one shard queue.  All methods are `&self` and
/// thread-safe; ordering is relaxed throughout (pure accounting).
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Blocks enqueued to this shard by the dispatcher.
    enqueued: AtomicU64,
    /// Blocks taken off this shard by its home worker(s).
    taken_home: AtomicU64,
    /// Blocks taken off this shard by a foreign worker (work stealing).
    stolen: AtomicU64,
    /// Requests rejected because this shard (the round-robin
    /// destination at the time) was at capacity.
    rejected: AtomicU64,
    /// Currently queued blocks.
    depth: AtomicU64,
    /// High-water mark of `depth`.
    peak_depth: AtomicU64,
}

impl ShardCounters {
    pub fn new() -> ShardCounters {
        ShardCounters::default()
    }

    /// One block queued; updates depth and its high-water mark.
    pub fn on_enqueue(&self) {
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_depth.fetch_max(d, Ordering::Relaxed);
    }

    /// One block dequeued, by a home worker (`stolen == false`) or a
    /// foreign one (`stolen == true`).
    pub fn on_take(&self, stolen: bool) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        if stolen {
            self.stolen.fetch_add(1, Ordering::Relaxed);
        } else {
            self.taken_home.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One request refused admission while this shard was the
    /// dispatcher's destination and its queue was full.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Current queued-block count (approximate under concurrency).
    pub fn depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Plain-data copy for reports.
    pub fn snapshot(&self) -> ShardSnapshot {
        ShardSnapshot {
            enqueued: self.enqueued.load(Ordering::Relaxed),
            taken_home: self.taken_home.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            peak_depth: self.peak_depth.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one shard's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSnapshot {
    pub enqueued: u64,
    pub taken_home: u64,
    pub stolen: u64,
    pub rejected: u64,
    pub peak_depth: u64,
}

impl ShardSnapshot {
    /// Blocks taken off this shard by anyone.
    pub fn taken(&self) -> u64 {
        self.taken_home + self.stolen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enqueue_take_balance() {
        let c = ShardCounters::new();
        for _ in 0..5 {
            c.on_enqueue();
        }
        assert_eq!(c.depth(), 5);
        c.on_take(false);
        c.on_take(true);
        let s = c.snapshot();
        assert_eq!(s.enqueued, 5);
        assert_eq!(s.taken_home, 1);
        assert_eq!(s.stolen, 1);
        assert_eq!(s.taken(), 2);
        assert_eq!(c.depth(), 3);
        assert_eq!(s.peak_depth, 5);
    }

    #[test]
    fn peak_depth_is_high_water() {
        let c = ShardCounters::new();
        c.on_enqueue();
        c.on_take(false);
        c.on_enqueue();
        c.on_enqueue();
        c.on_take(false);
        assert_eq!(c.snapshot().peak_depth, 2);
    }

    #[test]
    fn rejects_counted() {
        let c = ShardCounters::new();
        c.on_reject();
        c.on_reject();
        assert_eq!(c.snapshot().rejected, 2);
        assert_eq!(c.depth(), 0);
    }

    #[test]
    fn concurrent_updates_conserve_counts() {
        let c = std::sync::Arc::new(ShardCounters::new());
        let mut hs = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    c.on_enqueue();
                    c.on_take(t % 2 == 0);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.enqueued, 400);
        assert_eq!(s.taken(), 400);
        assert_eq!(c.depth(), 0);
        assert!(s.peak_depth >= 1);
    }
}
