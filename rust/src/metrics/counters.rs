//! Lock-free per-shard serving counters.
//!
//! Each shard of the sharded serving engine ([`crate::serve::shard`])
//! owns one [`ShardCounters`]: the dispatcher bumps it on enqueue and
//! rejection, workers bump it on take/steal.  Everything is a relaxed
//! atomic — the counters are observability, never control flow, so a
//! stale read is fine and the hot path pays one `fetch_add` per block
//! (blocks, not requests: a block is `max_batch` requests).
//!
//! [`ShardSnapshot`] is the plain-data copy taken at report time, used
//! by `dsg serve` summaries and `BENCH_serve.json`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters for one shard queue.  All methods are `&self` and
/// thread-safe; ordering is relaxed throughout (pure accounting).
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Blocks enqueued to this shard by the dispatcher.
    enqueued: AtomicU64,
    /// Blocks taken off this shard by its home worker(s).
    taken_home: AtomicU64,
    /// Blocks taken off this shard by a foreign worker (work stealing).
    stolen: AtomicU64,
    /// Requests rejected because this shard (the round-robin
    /// destination at the time) was at capacity.
    rejected: AtomicU64,
    /// Currently queued blocks.
    depth: AtomicU64,
    /// High-water mark of `depth`.
    peak_depth: AtomicU64,
}

impl ShardCounters {
    pub fn new() -> ShardCounters {
        ShardCounters::default()
    }

    /// One block queued; updates depth and its high-water mark.
    pub fn on_enqueue(&self) {
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_depth.fetch_max(d, Ordering::Relaxed);
    }

    /// One block dequeued, by a home worker (`stolen == false`) or a
    /// foreign one (`stolen == true`).
    pub fn on_take(&self, stolen: bool) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        if stolen {
            self.stolen.fetch_add(1, Ordering::Relaxed);
        } else {
            self.taken_home.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One request refused admission while this shard was the
    /// dispatcher's destination and its queue was full.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Current queued-block count (approximate under concurrency).
    pub fn depth(&self) -> u64 {
        self.depth.load(Ordering::Relaxed)
    }

    /// Plain-data copy for reports.
    pub fn snapshot(&self) -> ShardSnapshot {
        ShardSnapshot {
            enqueued: self.enqueued.load(Ordering::Relaxed),
            taken_home: self.taken_home.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            peak_depth: self.peak_depth.load(Ordering::Relaxed),
        }
    }
}

/// Process-wide recovery/fault accounting: everything the crash-safety
/// machinery does that an operator would want to see — injected faults,
/// checkpoint saves/retries/skips/resumes, serving retries, backoffs,
/// and disconnect reasons.  One global instance ([`recovery`]) so the
/// fault plane ([`crate::util::faults`]) and the recovery paths it
/// exercises can bump counters from any thread without plumbing.
///
/// Like [`ShardCounters`]: relaxed atomics, observability only, never
/// control flow.  Tests that assert on these counters must read a
/// snapshot before and after and compare deltas — the counters are
/// process-global and other tests may run concurrently.
#[derive(Debug, Default)]
pub struct RecoveryCounters {
    faults_injected: AtomicU64,
    ckpt_saves: AtomicU64,
    ckpt_retries: AtomicU64,
    ckpt_skipped: AtomicU64,
    ckpt_resumes: AtomicU64,
    batch_retries: AtomicU64,
    client_retries: AtomicU64,
    accept_backoffs: AtomicU64,
    conns_opened: AtomicU64,
    disconnects_idle: AtomicU64,
    disconnects_slow: AtomicU64,
    disconnects_error: AtomicU64,
    drains: AtomicU64,
    shard_retries: AtomicU64,
    shards_lost: AtomicU64,
    reshards: AtomicU64,
    frames_rejected: AtomicU64,
    stalls_absorbed: AtomicU64,
}

impl RecoveryCounters {
    pub const fn new() -> RecoveryCounters {
        RecoveryCounters {
            faults_injected: AtomicU64::new(0),
            ckpt_saves: AtomicU64::new(0),
            ckpt_retries: AtomicU64::new(0),
            ckpt_skipped: AtomicU64::new(0),
            ckpt_resumes: AtomicU64::new(0),
            batch_retries: AtomicU64::new(0),
            client_retries: AtomicU64::new(0),
            accept_backoffs: AtomicU64::new(0),
            conns_opened: AtomicU64::new(0),
            disconnects_idle: AtomicU64::new(0),
            disconnects_slow: AtomicU64::new(0),
            disconnects_error: AtomicU64::new(0),
            drains: AtomicU64::new(0),
            shard_retries: AtomicU64::new(0),
            shards_lost: AtomicU64::new(0),
            reshards: AtomicU64::new(0),
            frames_rejected: AtomicU64::new(0),
            stalls_absorbed: AtomicU64::new(0),
        }
    }

    /// A fault-plane site check matched its schedule and injected.
    pub fn on_fault_injected(&self) {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
    }
    /// One checkpoint durably on disk (post-rename).
    pub fn on_ckpt_save(&self) {
        self.ckpt_saves.fetch_add(1, Ordering::Relaxed);
    }
    /// One failed checkpoint-save attempt that will be retried.
    pub fn on_ckpt_retry(&self) {
        self.ckpt_retries.fetch_add(1, Ordering::Relaxed);
    }
    /// One torn/corrupt checkpoint file skipped by `load_latest_valid`.
    pub fn on_ckpt_skipped(&self) {
        self.ckpt_skipped.fetch_add(1, Ordering::Relaxed);
    }
    /// One training run resumed from an on-disk checkpoint.
    pub fn on_ckpt_resume(&self) {
        self.ckpt_resumes.fetch_add(1, Ordering::Relaxed);
    }
    /// One serving batch forward re-attempted after a failure.
    pub fn on_batch_retry(&self) {
        self.batch_retries.fetch_add(1, Ordering::Relaxed);
    }
    /// One client request re-sent after `Reject(overloaded)`.
    pub fn on_client_retry(&self) {
        self.client_retries.fetch_add(1, Ordering::Relaxed);
    }
    /// One accept-loop error absorbed with backoff (listener lived).
    pub fn on_accept_backoff(&self) {
        self.accept_backoffs.fetch_add(1, Ordering::Relaxed);
    }
    /// One wire connection accepted.
    pub fn on_conn_opened(&self) {
        self.conns_opened.fetch_add(1, Ordering::Relaxed);
    }
    /// One connection dropped for idling past the read deadline.
    pub fn on_disconnect_idle(&self) {
        self.disconnects_idle.fetch_add(1, Ordering::Relaxed);
    }
    /// One connection dropped because its write queue overflowed.
    pub fn on_disconnect_slow(&self) {
        self.disconnects_slow.fetch_add(1, Ordering::Relaxed);
    }
    /// One connection dropped on a read/decode error.
    pub fn on_disconnect_error(&self) {
        self.disconnects_error.fetch_add(1, Ordering::Relaxed);
    }
    /// One graceful server drain completed.
    pub fn on_drain(&self) {
        self.drains.fetch_add(1, Ordering::Relaxed);
    }
    /// One data-parallel leaf task re-attempted (error, rejected frame,
    /// or deadline trip).
    pub fn on_shard_retry(&self) {
        self.shard_retries.fetch_add(1, Ordering::Relaxed);
    }
    /// One shard declared lost for the rest of the run.
    pub fn on_shard_lost(&self) {
        self.shards_lost.fetch_add(1, Ordering::Relaxed);
    }
    /// One deterministic re-shard of outstanding work onto survivors.
    pub fn on_reshard(&self) {
        self.reshards.fetch_add(1, Ordering::Relaxed);
    }
    /// One gradient frame rejected by the canonical-form check (torn or
    /// corrupt) — never summed, always recomputed.
    pub fn on_frame_rejected(&self) {
        self.frames_rejected.fetch_add(1, Ordering::Relaxed);
    }
    /// One injected stall absorbed as pure delay.
    pub fn on_stall_absorbed(&self) {
        self.stalls_absorbed.fetch_add(1, Ordering::Relaxed);
    }

    /// Plain-data copy for reports and test deltas.
    pub fn snapshot(&self) -> RecoverySnapshot {
        RecoverySnapshot {
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            ckpt_saves: self.ckpt_saves.load(Ordering::Relaxed),
            ckpt_retries: self.ckpt_retries.load(Ordering::Relaxed),
            ckpt_skipped: self.ckpt_skipped.load(Ordering::Relaxed),
            ckpt_resumes: self.ckpt_resumes.load(Ordering::Relaxed),
            batch_retries: self.batch_retries.load(Ordering::Relaxed),
            client_retries: self.client_retries.load(Ordering::Relaxed),
            accept_backoffs: self.accept_backoffs.load(Ordering::Relaxed),
            conns_opened: self.conns_opened.load(Ordering::Relaxed),
            disconnects_idle: self.disconnects_idle.load(Ordering::Relaxed),
            disconnects_slow: self.disconnects_slow.load(Ordering::Relaxed),
            disconnects_error: self.disconnects_error.load(Ordering::Relaxed),
            drains: self.drains.load(Ordering::Relaxed),
            shard_retries: self.shard_retries.load(Ordering::Relaxed),
            shards_lost: self.shards_lost.load(Ordering::Relaxed),
            reshards: self.reshards.load(Ordering::Relaxed),
            frames_rejected: self.frames_rejected.load(Ordering::Relaxed),
            stalls_absorbed: self.stalls_absorbed.load(Ordering::Relaxed),
        }
    }
}

/// The process-wide [`RecoveryCounters`] instance.
pub fn recovery() -> &'static RecoveryCounters {
    static RECOVERY: RecoveryCounters = RecoveryCounters::new();
    &RECOVERY
}

/// Point-in-time copy of the recovery counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoverySnapshot {
    pub faults_injected: u64,
    pub ckpt_saves: u64,
    pub ckpt_retries: u64,
    pub ckpt_skipped: u64,
    pub ckpt_resumes: u64,
    pub batch_retries: u64,
    pub client_retries: u64,
    pub accept_backoffs: u64,
    pub conns_opened: u64,
    pub disconnects_idle: u64,
    pub disconnects_slow: u64,
    pub disconnects_error: u64,
    pub drains: u64,
    pub shard_retries: u64,
    pub shards_lost: u64,
    pub reshards: u64,
    pub frames_rejected: u64,
    pub stalls_absorbed: u64,
}

impl RecoverySnapshot {
    /// Field-wise `self - earlier`, saturating: the delta attributable
    /// to work done between the two snapshots.
    pub fn since(&self, earlier: &RecoverySnapshot) -> RecoverySnapshot {
        RecoverySnapshot {
            faults_injected: self.faults_injected.saturating_sub(earlier.faults_injected),
            ckpt_saves: self.ckpt_saves.saturating_sub(earlier.ckpt_saves),
            ckpt_retries: self.ckpt_retries.saturating_sub(earlier.ckpt_retries),
            ckpt_skipped: self.ckpt_skipped.saturating_sub(earlier.ckpt_skipped),
            ckpt_resumes: self.ckpt_resumes.saturating_sub(earlier.ckpt_resumes),
            batch_retries: self.batch_retries.saturating_sub(earlier.batch_retries),
            client_retries: self.client_retries.saturating_sub(earlier.client_retries),
            accept_backoffs: self.accept_backoffs.saturating_sub(earlier.accept_backoffs),
            conns_opened: self.conns_opened.saturating_sub(earlier.conns_opened),
            disconnects_idle: self.disconnects_idle.saturating_sub(earlier.disconnects_idle),
            disconnects_slow: self.disconnects_slow.saturating_sub(earlier.disconnects_slow),
            disconnects_error: self.disconnects_error.saturating_sub(earlier.disconnects_error),
            drains: self.drains.saturating_sub(earlier.drains),
            shard_retries: self.shard_retries.saturating_sub(earlier.shard_retries),
            shards_lost: self.shards_lost.saturating_sub(earlier.shards_lost),
            reshards: self.reshards.saturating_sub(earlier.reshards),
            frames_rejected: self.frames_rejected.saturating_sub(earlier.frames_rejected),
            stalls_absorbed: self.stalls_absorbed.saturating_sub(earlier.stalls_absorbed),
        }
    }

    /// True if any counter is nonzero (gates report printing: quiet
    /// runs stay quiet).
    pub fn any(&self) -> bool {
        *self != RecoverySnapshot::default()
    }

    /// One-line human summary of the nonzero fields.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for (name, v) in [
            ("faults_injected", self.faults_injected),
            ("ckpt_saves", self.ckpt_saves),
            ("ckpt_retries", self.ckpt_retries),
            ("ckpt_skipped", self.ckpt_skipped),
            ("ckpt_resumes", self.ckpt_resumes),
            ("batch_retries", self.batch_retries),
            ("client_retries", self.client_retries),
            ("accept_backoffs", self.accept_backoffs),
            ("conns_opened", self.conns_opened),
            ("disconnects_idle", self.disconnects_idle),
            ("disconnects_slow", self.disconnects_slow),
            ("disconnects_error", self.disconnects_error),
            ("drains", self.drains),
            ("shard_retries", self.shard_retries),
            ("shards_lost", self.shards_lost),
            ("reshards", self.reshards),
            ("frames_rejected", self.frames_rejected),
            ("stalls_absorbed", self.stalls_absorbed),
        ] {
            if v > 0 {
                parts.push(format!("{name}={v}"));
            }
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// Point-in-time copy of one shard's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSnapshot {
    pub enqueued: u64,
    pub taken_home: u64,
    pub stolen: u64,
    pub rejected: u64,
    pub peak_depth: u64,
}

impl ShardSnapshot {
    /// Blocks taken off this shard by anyone.
    pub fn taken(&self) -> u64 {
        self.taken_home + self.stolen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enqueue_take_balance() {
        let c = ShardCounters::new();
        for _ in 0..5 {
            c.on_enqueue();
        }
        assert_eq!(c.depth(), 5);
        c.on_take(false);
        c.on_take(true);
        let s = c.snapshot();
        assert_eq!(s.enqueued, 5);
        assert_eq!(s.taken_home, 1);
        assert_eq!(s.stolen, 1);
        assert_eq!(s.taken(), 2);
        assert_eq!(c.depth(), 3);
        assert_eq!(s.peak_depth, 5);
    }

    #[test]
    fn peak_depth_is_high_water() {
        let c = ShardCounters::new();
        c.on_enqueue();
        c.on_take(false);
        c.on_enqueue();
        c.on_enqueue();
        c.on_take(false);
        assert_eq!(c.snapshot().peak_depth, 2);
    }

    #[test]
    fn recovery_snapshot_delta_and_summary() {
        let c = RecoveryCounters::new();
        let before = c.snapshot();
        assert!(!before.any());
        assert_eq!(before.summary(), "none");
        c.on_fault_injected();
        c.on_ckpt_save();
        c.on_ckpt_save();
        c.on_disconnect_slow();
        let d = c.snapshot().since(&before);
        assert!(d.any());
        assert_eq!(d.faults_injected, 1);
        assert_eq!(d.ckpt_saves, 2);
        assert_eq!(d.disconnects_slow, 1);
        assert_eq!(d.summary(), "faults_injected=1 ckpt_saves=2 disconnects_slow=1");
    }

    #[test]
    fn global_recovery_is_shared() {
        let before = recovery().snapshot();
        recovery().on_drain();
        let d = recovery().snapshot().since(&before);
        assert!(d.drains >= 1);
    }

    #[test]
    fn rejects_counted() {
        let c = ShardCounters::new();
        c.on_reject();
        c.on_reject();
        assert_eq!(c.snapshot().rejected, 2);
        assert_eq!(c.depth(), 0);
    }

    #[test]
    fn concurrent_updates_conserve_counts() {
        let c = std::sync::Arc::new(ShardCounters::new());
        let mut hs = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    c.on_enqueue();
                    c.on_take(t % 2 == 0);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.enqueued, 400);
        assert_eq!(s.taken(), 400);
        assert_eq!(c.depth(), 0);
        assert!(s.peak_depth >= 1);
    }
}
