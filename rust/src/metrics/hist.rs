//! Log-bucketed latency histogram — the serving subsystem's aggregation
//! currency.
//!
//! Worker threads each own a private histogram and the server merges
//! them at shutdown, so recording is lock-free on the hot path.  Buckets
//! are geometric (10 per decade) spanning 1 µs .. ~100 s plus an
//! underflow and an overflow slot — 82 counters total, one flat
//! allocation: cheap to clone, cheap to merge, and accurate to ~±12%
//! per bucket, plenty for p50/p95/p99 reporting.  Exact min/max/sum are
//! tracked alongside so the tails and the mean stay exact.

/// Lower edge of bucket 0, in seconds.
const MIN_SECS: f64 = 1e-6;
/// Buckets per decade (geometric growth 10^(1/10) ≈ 1.26x per bucket).
const PER_DECADE: f64 = 10.0;
/// 8 decades (1 µs .. 100 s) plus an overflow bucket at each end.
const N_BUCKETS: usize = 82;

/// Fixed-size log-bucketed histogram over non-negative durations.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }
}

fn bucket_index(secs: f64) -> usize {
    if secs < MIN_SECS {
        return 0;
    }
    let i = ((secs / MIN_SECS).log10() * PER_DECADE).floor() as usize + 1;
    i.min(N_BUCKETS - 1)
}

/// Geometric midpoint of a bucket, used as the percentile estimate.
fn bucket_mid(idx: usize) -> f64 {
    if idx == 0 {
        return MIN_SECS * 0.5;
    }
    let lo = MIN_SECS * 10f64.powf((idx - 1) as f64 / PER_DECADE);
    lo * 10f64.powf(0.5 / PER_DECADE)
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one duration in seconds (negative values clamp to 0).
    pub fn record(&mut self, secs: f64) {
        let secs = secs.max(0.0);
        self.buckets[bucket_index(secs)] += 1;
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    /// Fold another histogram into this one (worker-stat aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Estimated percentile (p in [0, 1]): the geometric midpoint of the
    /// bucket holding the rank-p sample, clamped to the exact observed
    /// min/max so the extremes never over/under-shoot.
    ///
    /// Rank follows the ceil nearest-rank convention (the smallest sample
    /// with at least `p` of the mass at or below it) — the old
    /// `.round()` rank rounded half-up, reporting `max` for the p50 of
    /// two samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // 1-based rank in [1, count]
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank == 1 {
            return self.min;
        }
        if rank == self.count {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// "p50/p95/p99" one-line summary with `fmt_secs` units.
    pub fn summary(&self) -> String {
        format!(
            "p50 {} p95 {} p99 {} (n={})",
            super::fmt_secs(self.percentile(0.50)),
            super::fmt_secs(self.percentile(0.95)),
            super::fmt_secs(self.percentile(0.99)),
            self.count
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn bucket_index_monotone_and_bounded() {
        let mut last = 0usize;
        let mut s = 1e-8;
        while s < 1e4 {
            let i = bucket_index(s);
            assert!(i >= last, "index not monotone at {s}");
            assert!(i < N_BUCKETS);
            last = i;
            s *= 1.7;
        }
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(1e9), N_BUCKETS - 1);
    }

    #[test]
    fn bucket_mid_lands_in_own_bucket() {
        for idx in 1..N_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_mid(idx)), idx, "bucket {idx}");
        }
    }

    #[test]
    fn percentiles_approximate_known_distribution() {
        let mut h = LatencyHistogram::new();
        // 100 samples: 1ms .. 100ms linearly
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        // geometric buckets are ~±12% wide; allow 15% relative error
        let p50 = h.percentile(0.5);
        assert!((p50 - 0.050).abs() / 0.050 < 0.15, "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!((p99 - 0.099).abs() / 0.099 < 0.15, "p99 {p99}");
        // extremes are exact
        assert_eq!(h.percentile(0.0), h.min());
        assert_eq!(h.percentile(1.0), h.max());
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 0.1);
    }

    #[test]
    fn two_sample_median_is_lower_sample() {
        // regression: .round() nearest-rank reported max for p50 of two
        let mut h = LatencyHistogram::new();
        h.record(0.001);
        h.record(0.100);
        assert_eq!(h.percentile(0.5), 0.001);
        assert_eq!(h.percentile(0.0), 0.001);
        assert_eq!(h.percentile(1.0), 0.100);
        // single sample: every percentile is that sample
        let mut one = LatencyHistogram::new();
        one.record(0.007);
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(one.percentile(p), 0.007, "p{p}");
        }
    }

    #[test]
    fn merge_equals_recording_everything_in_one() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        for i in 0..200 {
            let v = 1e-5 * (1.0 + i as f64);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for p in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(p), whole.percentile(p), "p{p}");
        }
    }

    #[test]
    fn summary_mentions_count() {
        let mut h = LatencyHistogram::new();
        h.record(0.002);
        assert!(h.summary().contains("n=1"));
    }
}
