//! Measured training-tape memory accounting — §3.3 / Fig 6 made real.
//!
//! The analytic model ([`crate::memmodel::memory`]) PREDICTS the
//! training footprint from shapes and a sparsity; this meter MEASURES
//! it: the native training engine reports every tape record it stashes
//! during the forward ([`MemoryMeter::alloc`]) and every record it
//! releases as the backward walk consumes it ([`MemoryMeter::free`]),
//! so `peak()` is the real high-water mark of tape bytes for the step
//! and `dense_peak()` is what the same tape would have cost stored
//! dense.  The cross-check the tests pin down: a ZVC-stored activation's
//! `stored_bytes` equals `zvc::zvc_bytes_nnz(elems, nnz)` exactly, and
//! the dense/ZVC ratio moves with gamma the way `memmodel` predicts.

/// One taped buffer, as the engine accounted it.
#[derive(Clone, Debug)]
pub struct TapeAlloc {
    /// Unit index in the forward topology.
    pub unit: usize,
    /// Which buffer of the unit: "x" (unit input), "s"/"s1"/"s2"
    /// (post-relu pre-BN activations), "mask" (DRS selection), "bn"
    /// (taped batch statistics), "idx" (maxpool argmax routes).
    pub part: &'static str,
    /// f32 (or u32 for "idx") element count.
    pub elems: usize,
    /// Non-zero elements.  == `elems` for non-activation parts AND for
    /// unmeasured activation records: a dense-tape run deliberately
    /// skips the counting sweep, so only ZVC-tape runs (where the count
    /// is a byproduct of the store decision) report real sparsity.
    pub nnz: usize,
    /// Bytes a dense store of this buffer costs.
    pub dense_bytes: u64,
    /// Bytes actually held on the tape.
    pub stored_bytes: u64,
}

impl TapeAlloc {
    /// Is this an activation record (the ZVC-compressible kind)?
    pub fn is_act(&self) -> bool {
        matches!(self.part, "x" | "s" | "s1" | "s2" | "h1")
    }

    /// Measured zero fraction of the buffer.
    pub fn sparsity(&self) -> f64 {
        if self.elems == 0 {
            return 0.0;
        }
        1.0 - self.nnz as f64 / self.elems as f64
    }
}

/// Live/peak tape-byte tracking for one training step, with the
/// per-record breakdown kept for reporting and cross-checks.
#[derive(Clone, Debug, Default)]
pub struct MemoryMeter {
    live: u64,
    peak: u64,
    allocs: Vec<TapeAlloc>,
}

impl MemoryMeter {
    pub fn new() -> MemoryMeter {
        MemoryMeter::default()
    }

    /// Forget the previous step (capacity reused).
    pub fn reset(&mut self) {
        self.live = 0;
        self.peak = 0;
        self.allocs.clear();
    }

    /// Record one tape record coming live during the forward.
    pub fn alloc(&mut self, a: TapeAlloc) {
        self.live += a.stored_bytes;
        self.peak = self.peak.max(self.live);
        self.allocs.push(a);
    }

    /// Record tape bytes released by the backward walk.
    pub fn free(&mut self, stored_bytes: u64) {
        self.live = self.live.saturating_sub(stored_bytes);
    }

    /// Release every record of `unit`, as it was recorded at alloc time
    /// — the free side cannot drift from the alloc side because it IS
    /// the alloc side.
    pub fn free_unit(&mut self, unit: usize) {
        let bytes: u64 = self
            .allocs
            .iter()
            .filter(|a| a.unit == unit)
            .map(|a| a.stored_bytes)
            .sum();
        self.free(bytes);
    }

    /// Tape bytes currently live.
    pub fn live(&self) -> u64 {
        self.live
    }

    /// High-water mark of tape bytes this step (every record is live at
    /// the forward/backward turnover, so this is the training-memory
    /// number Fig 6 is about).
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Every record of the step, in forward (allocation) order.
    pub fn allocs(&self) -> &[TapeAlloc] {
        &self.allocs
    }

    /// What the same tape would have peaked at stored dense.
    pub fn dense_peak(&self) -> u64 {
        self.allocs.iter().map(|a| a.dense_bytes).sum()
    }

    /// Peak bytes of the activation records only (dense, stored).
    pub fn act_bytes(&self) -> (u64, u64) {
        let mut dense = 0u64;
        let mut stored = 0u64;
        for a in self.allocs.iter().filter(|a| a.is_act()) {
            dense += a.dense_bytes;
            stored += a.stored_bytes;
        }
        (dense, stored)
    }

    /// Measured dense/stored reduction at peak (> 1 means the
    /// compressed tape won); 1.0 for an empty meter.
    pub fn reduction(&self) -> f64 {
        if self.peak == 0 {
            return 1.0;
        }
        self.dense_peak() as f64 / self.peak as f64
    }

    /// Activation-only reduction (the paper's "up to 7.1x" axis).
    pub fn act_reduction(&self) -> f64 {
        let (dense, stored) = self.act_bytes();
        if stored == 0 {
            return 1.0;
        }
        dense as f64 / stored as f64
    }

    /// Measured zero fraction over all activation records.
    pub fn act_sparsity(&self) -> f64 {
        let mut elems = 0usize;
        let mut nnz = 0usize;
        for a in self.allocs.iter().filter(|a| a.is_act()) {
            elems += a.elems;
            nnz += a.nnz;
        }
        if elems == 0 {
            return 0.0;
        }
        1.0 - nnz as f64 / elems as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn act(unit: usize, elems: usize, nnz: usize, stored: u64) -> TapeAlloc {
        TapeAlloc { unit, part: "s", elems, nnz, dense_bytes: 4 * elems as u64, stored_bytes: stored }
    }

    #[test]
    fn live_peak_and_reduction() {
        let mut m = MemoryMeter::new();
        m.alloc(act(0, 100, 50, 213));
        m.alloc(act(1, 100, 100, 400));
        m.alloc(TapeAlloc {
            unit: 1,
            part: "mask",
            elems: 100,
            nnz: 100,
            dense_bytes: 50,
            stored_bytes: 50,
        });
        assert_eq!(m.live(), 663);
        assert_eq!(m.peak(), 663);
        assert_eq!(m.dense_peak(), 850);
        m.free(400);
        assert_eq!(m.live(), 263);
        assert_eq!(m.peak(), 663, "peak survives frees");
        assert!((m.reduction() - 850.0 / 663.0).abs() < 1e-12);
        let (ad, astored) = m.act_bytes();
        assert_eq!((ad, astored), (800, 613));
        assert!((m.act_sparsity() - 0.25).abs() < 1e-12);
        m.reset();
        assert_eq!(m.peak(), 0);
        assert_eq!(m.reduction(), 1.0);
        assert!(m.allocs().is_empty());
    }

    #[test]
    fn free_unit_releases_exactly_what_was_allocated() {
        let mut m = MemoryMeter::new();
        m.alloc(act(0, 100, 50, 213));
        m.alloc(act(0, 64, 64, 256)); // second record of the same unit
        m.alloc(act(1, 100, 100, 400));
        assert_eq!(m.peak(), 869);
        m.free_unit(1);
        assert_eq!(m.live(), 469);
        m.free_unit(0);
        assert_eq!(m.live(), 0, "free side derives from the alloc records");
        m.free_unit(7); // unknown unit: no records, no-op
        assert_eq!(m.live(), 0);
    }

    #[test]
    fn sparsity_per_alloc() {
        let a = act(3, 200, 50, 0);
        assert!((a.sparsity() - 0.75).abs() < 1e-12);
        assert!(a.is_act());
        let empty = act(0, 0, 0, 0);
        assert_eq!(empty.sparsity(), 0.0);
    }
}
