//! Layer-wise timing engine for Fig 8(a): run GEMM / VMM / DSG on the
//! same layer shapes and report median wall-clock + speedup ratios.

use crate::drs::projection::{ternary_r, TernaryIndex};
use crate::drs::project_weights_idx;
use crate::tensor::{ops, Tensor};
use crate::util::Pcg32;

/// One VGG8 layer shape in (n_PQ, n_CRS, n_K) VMM form (paper Table 1).
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub name: &'static str,
    pub n_pq: usize,
    pub n_crs: usize,
    pub n_k: usize,
}

/// The VGG8 CONV layers the paper times in Fig 8(a)/Table 1.
pub const VGG8_LAYERS: &[LayerShape] = &[
    LayerShape { name: "conv2", n_pq: 1024, n_crs: 1152, n_k: 128 },
    LayerShape { name: "conv3", n_pq: 256, n_crs: 1152, n_k: 256 },
    LayerShape { name: "conv4", n_pq: 256, n_crs: 2304, n_k: 256 },
    LayerShape { name: "conv5", n_pq: 64, n_crs: 2304, n_k: 512 },
    LayerShape { name: "conv6", n_pq: 64, n_crs: 4608, n_k: 512 },
];

/// Timing result for one layer at one sparsity.
///
/// Matching the paper's Fig 8(a) protocol, `dsg_secs` is the execution
/// time of the layer AFTER the dimension-reduction search ("we evaluate
/// the execution time of these layers after the dimension-reduction
/// search"); the search itself is timed separately in `drs_secs` and its
/// op-count accounting lives in the Fig 7 cost model.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub shape: LayerShape,
    pub gamma: f32,
    pub gemm_secs: f64,
    pub vmm_secs: f64,
    pub dsg_secs: f64,
    pub drs_secs: f64,
    pub density: f64,
}

impl LayerTiming {
    pub fn speedup_vs_vmm(&self) -> f64 {
        self.vmm_secs / self.dsg_secs
    }
    pub fn speedup_vs_gemm(&self) -> f64 {
        self.gemm_secs / self.dsg_secs
    }
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

fn time_n(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
    let mut ts = Vec::with_capacity(reps);
    for _ in 0..reps {
        ts.push(f());
    }
    median(ts)
}

/// Benchmark one layer shape at one sparsity level.
///
/// `eps` picks the projection dim via the calibrated JLL model; `reps`
/// repetitions, median reported.  All three engines compute the same
/// product so the comparison is apples-to-apples.
pub fn bench_layer(
    shape: LayerShape,
    gamma: f32,
    eps: f64,
    reps: usize,
    seed: u64,
) -> LayerTiming {
    let mut rng = Pcg32::seeded(seed);
    let (m, d, n) = (shape.n_pq, shape.n_crs, shape.n_k);
    let k = crate::costmodel::jll::projection_dim(eps, n, d);
    let x = Tensor::new(&[m, d], rng.normal_vec(m * d, 1.0));
    let w = Tensor::new(&[d, n], rng.normal_vec(d * n, (2.0 / d as f32).sqrt()));
    let wt = ops::transpose(&w);
    let r = ternary_r(&mut rng, k, d, 3);
    // index built ONCE, shared by the weight projection and the per-rep
    // row projections (project_weights used to rebuild it internally)
    let ridx = TernaryIndex::from_dense(&r);
    let wp = project_weights_idx(&ridx, &w);

    // warmup
    let _ = ops::matmul_blocked(&x, &w);

    let gemm_secs = time_n(reps, || {
        let (_, t) = crate::util::time_secs(|| ops::matmul_blocked(&x, &w));
        t
    });
    let vmm_secs = time_n(reps, || {
        let (_, t) = crate::util::time_secs(|| super::vmm(&x, &wt));
        t
    });
    // DRS search: projection + low-dim virtual VMM + shared threshold,
    // into reused workspace buffers (the search itself is also
    // allocation-free in steady state, like the serving hot path).
    let mut mask = crate::drs::topk::RowMask::new();
    let mut xp = vec![0.0f32; m * k];
    let mut virt = vec![0.0f32; m * n];
    let mut thr_scratch: Vec<f32> = Vec::new();
    let drs_secs = time_n(reps, || {
        let ((), t) = crate::util::time_secs(|| {
            for i in 0..m {
                ridx.project_row(&x.data()[i * d..(i + 1) * d], &mut xp[i * k..(i + 1) * k]);
            }
            ops::matmul_blocked_into(&xp, m, k, wp.data(), n, &mut virt);
            let thr =
                crate::drs::topk::shared_threshold_slice(&virt, n, gamma, &mut thr_scratch);
            mask.fill_from_threshold(&virt, m, n, thr);
        });
        t
    });
    let density = mask.density();
    // Layer execution after the search (the Fig 8a measurement): the
    // compact mask jumps straight to the selected output neurons.
    let dsg_secs = time_n(reps, || {
        let (_, t) = crate::util::time_secs(|| super::dsg_vmm_rowmask(&x, &wt, &mask));
        t
    });

    LayerTiming { shape, gamma, gemm_secs, vmm_secs, dsg_secs, drs_secs, density }
}

/// Run the full Fig 8(a) sweep: all VGG8 layers x sparsity levels.
pub fn fig8_sweep(gammas: &[f32], eps: f64, reps: usize) -> Vec<LayerTiming> {
    let mut out = Vec::new();
    for (li, &shape) in VGG8_LAYERS.iter().enumerate() {
        for &g in gammas {
            out.push(bench_layer(shape, g, eps, reps, 100 + li as u64));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engines_agree_numerically() {
        let shape = LayerShape { name: "t", n_pq: 32, n_crs: 96, n_k: 24 };
        let t = bench_layer(shape, 0.0, 0.5, 1, 7);
        // at gamma=0 density is 1 and all engines computed the same thing
        assert_eq!(t.density, 1.0);
        assert!(t.gemm_secs > 0.0 && t.vmm_secs > 0.0 && t.dsg_secs > 0.0);
    }

    #[test]
    fn dsg_beats_vmm_at_high_sparsity() {
        // On a reasonably sized layer the column skip must pay off vs the
        // naive VMM (the paper's headline Fig 8a direction).
        let shape = LayerShape { name: "t", n_pq: 256, n_crs: 1152, n_k: 128 };
        let t = bench_layer(shape, 0.9, 0.5, 3, 8);
        assert!(
            t.speedup_vs_vmm() > 3.0,
            "DSG vs VMM speedup too small: {:.2} (dsg {:.4}s vmm {:.4}s)",
            t.speedup_vs_vmm(),
            t.dsg_secs,
            t.vmm_secs
        );
        assert!((t.density - 0.1).abs() < 0.05);
    }

    #[test]
    fn vgg8_shapes_match_table1() {
        assert_eq!(VGG8_LAYERS.len(), 5);
        assert_eq!(VGG8_LAYERS[0].n_crs, 1152);
        assert_eq!(VGG8_LAYERS[4].n_crs, 4608);
    }
}
