//! CPU sparse execution engine — the Fig 8(a) substrate.
//!
//! The paper evaluates layer-wise execution time of DSG's vector-wise
//! structured sparsity against two baselines on Intel MKL: a row-by-row
//! VMM and a dense GEMM.  MKL is unavailable here; `tensor::ops` provides
//! the blocked-GEMM stand-in and this module implements:
//!
//!   * `vmm`         — row-loop dense vector-matrix multiply (BL of Fig 8a)
//!   * `dsg_vmm`     — per-row masked VMM over a dense f32 mask (kept as
//!                     the reference and the bench baseline)
//!   * `dsg_vmm_rowmask` — masked VMM over the compact [`RowMask`]
//!                     (per-row selected-index lists): jumps straight to
//!                     selected neurons instead of branch-scanning all n
//!                     columns (Fig 3b, minus the scan)
//!   * `dsg_layer`   — the full DSG pipeline for one layer: ternary
//!                     projection -> low-dim virtual VMM -> shared top-k
//!                     threshold -> masked high-dim VMM
//!
//! `pool` holds the persistent worker pool behind the `parallel`
//! engines; `engine` is the Fig 8(a) layer-timing harness.
//!
//! Speedup *ratios* VMM/DSG and GEMM/DSG are what Fig 8(a) claims
//! (2.0/5.0/8.5x over VMM and 0.6/1.6/2.7x over GEMM at 50/80/90%).

pub mod engine;
pub mod parallel;
pub mod pool;
pub mod simd;

use crate::drs::{projection::TernaryIndex, topk};
use crate::tensor::{ops, Tensor};

pub use crate::drs::topk::RowMask;

/// Row-by-row dense VMM over a TRANSPOSED weight matrix wt (n, d): each
/// output neuron is an independent inner product over contiguous memory —
/// the paper's "VMM" baseline (each sliding window is an independent
/// vector-matrix product), with the same memory layout as the DSG engine
/// so the comparison isolates the column *skipping*, not cache layout.
pub fn vmm(x: &Tensor, wt: &Tensor) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    let mut out = vec![0.0f32; m * n];
    let xd = x.data();
    let wd = wt.data();
    for i in 0..m {
        let row = &xd[i * d..(i + 1) * d];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let wrow = &wd[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            let mut p = 0;
            while p + 4 <= d {
                acc += row[p] * wrow[p]
                    + row[p + 1] * wrow[p + 1]
                    + row[p + 2] * wrow[p + 2]
                    + row[p + 3] * wrow[p + 3];
                p += 4;
            }
            while p < d {
                acc += row[p] * wrow[p];
                p += 1;
            }
            orow[j] = acc;
        }
    }
    Tensor::new(&[m, n], out)
}

/// DSG masked VMM over a transposed weight matrix wt (n, d): for each
/// row, compute ONLY the output neurons selected by `mask` — the
/// vector-wise structured skip of Fig 3(b).  Non-selected outputs are 0.
pub fn dsg_vmm(x: &Tensor, wt: &Tensor, mask: &Tensor) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.shape(), &[m, n]);
    let mut out = vec![0.0f32; m * n];
    let xd = x.data();
    let wd = wt.data();
    let md = mask.data();
    for i in 0..m {
        let row = &xd[i * d..(i + 1) * d];
        let orow = &mut out[i * n..(i + 1) * n];
        let mrow = &md[i * n..(i + 1) * n];
        for j in 0..n {
            if mrow[j] == 0.0 {
                continue; // skip the whole weight column
            }
            let wrow = &wd[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            let mut p = 0;
            while p + 4 <= d {
                acc += row[p] * wrow[p]
                    + row[p + 1] * wrow[p + 1]
                    + row[p + 2] * wrow[p + 2]
                    + row[p + 3] * wrow[p + 3];
                p += 4;
            }
            while p < d {
                acc += row[p] * wrow[p];
                p += 1;
            }
            orow[j] = acc;
        }
    }
    Tensor::new(&[m, n], out)
}

/// DSG masked VMM over a compact [`RowMask`]: per row, jump straight to
/// the selected output neurons instead of branch-scanning all n columns.
/// Bit-exact with [`dsg_vmm`] for the same selection (ascending indices,
/// same per-dot accumulation order); a full mask (gamma = 0 keep-all)
/// takes a dense fast path with no index indirection.
pub fn dsg_vmm_rowmask(x: &Tensor, wt: &Tensor, mask: &RowMask) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    let mut out = vec![0.0f32; m * n];
    parallel::vmm_rowmask_chunk(x.data(), wt.data(), d, n, mask, 0, m, &mut out);
    Tensor::new(&[m, n], out)
}

/// Serial COMPOUND masked VMM: input- and output-side sparsity exploited
/// together (gather each row's nonzero coordinates once, accumulate only
/// into the selected outputs).  Bit-exact with [`dsg_vmm_rowmask`] /
/// [`dsg_vmm`]; returns the product and the realized multiply-add count
/// — ops ~ nnz(in) * sel(out), the paper's (1 - gamma)^2 claim made
/// measurable.
pub fn dsg_vmm_compound(x: &Tensor, wt: &Tensor, mask: &RowMask) -> (Tensor, u64) {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    let mut out = vec![0.0f32; m * n];
    let realized = parallel::vmm_rowmask_compound_chunk(x.data(), wt.data(), d, n, mask, 0, m, &mut out);
    (Tensor::new(&[m, n], out), realized)
}

/// Result of one full DSG layer execution on the host engine.
pub struct DsgLayerOut {
    pub y: Tensor,
    /// Compact selection (use [`RowMask::to_dense`] for an f32 mask).
    pub mask: RowMask,
    pub density: f64,
}

/// Full DSG pipeline for one layer (the Fig 8a "DSG" measurement):
/// ternary projection of every row, low-dim virtual VMM, shared top-k
/// threshold from sample 0, masked high-dim VMM.
///
/// `x` (m, d); `wt` (n, d) transposed weights; `wp` (k, n) projected
/// weights; `ridx` the index-form ternary R.
pub fn dsg_layer(
    x: &Tensor,
    wt: &Tensor,
    wp: &Tensor,
    ridx: &TernaryIndex,
    gamma: f32,
) -> DsgLayerOut {
    let m = x.shape()[0];
    let n = wt.shape()[0];
    let k = ridx.k;
    // 1) project rows (multiplication-free adds)
    let mut xp = vec![0.0f32; m * k];
    for i in 0..m {
        ridx.project_row(
            &x.data()[i * ridx.d..(i + 1) * ridx.d],
            &mut xp[i * k..(i + 1) * k],
        );
    }
    let xp = Tensor::new(&[m, k], xp);
    // 2) low-dimensional virtual VMM (m, k) x (k, n)
    let virt = ops::matmul_blocked(&xp, wp);
    // 3) shared threshold + compact selection
    let t = topk::shared_threshold(&virt, gamma);
    let rmask = RowMask::from_threshold(&virt, t);
    // 4) masked high-dimensional VMM jumping straight to selected columns
    let y = dsg_vmm_rowmask(x, wt, &rmask);
    let density = rmask.density();
    DsgLayerOut { y, mask: rmask, density }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drs::projection::ternary_r;
    use crate::util::Pcg32;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn vmm_matches_gemm() {
        let mut rng = Pcg32::seeded(51);
        let x = randn(&mut rng, &[13, 40]);
        let w = randn(&mut rng, &[40, 21]);
        let a = vmm(&x, &ops::transpose(&w));
        let b = ops::matmul_blocked(&x, &w);
        assert!(a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn dsg_vmm_computes_only_selected() {
        let mut rng = Pcg32::seeded(52);
        let x = randn(&mut rng, &[6, 32]);
        let w = randn(&mut rng, &[32, 10]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[6, 10], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let got = dsg_vmm(&x, &wt, &mask);
        let full = ops::matmul_naive(&x, &w);
        for i in 0..6 {
            for j in 0..10 {
                let want = if mask.at2(i, j) != 0.0 { full.at2(i, j) } else { 0.0 };
                assert!((got.at2(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn dsg_vmm_rowmask_matches_dense_mask() {
        let mut rng = Pcg32::seeded(56);
        let x = randn(&mut rng, &[7, 48]);
        let w = randn(&mut rng, &[48, 13]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[7, 13], |i| if i % 5 < 2 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        assert_eq!(dsg_vmm(&x, &wt, &mask), dsg_vmm_rowmask(&x, &wt, &rm));
        // keep-all fast path: full mask == dense row sweep, bit-exact
        let full = Tensor::full(&[7, 13], 1.0);
        let rf = RowMask::from_dense(&full);
        assert!(rf.is_full());
        assert_eq!(vmm(&x, &wt), dsg_vmm_rowmask(&x, &wt, &rf));
    }

    #[test]
    fn dsg_layer_density_tracks_gamma() {
        let mut rng = Pcg32::seeded(53);
        let (m, d, n, k) = (32, 256, 64, 64);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let r = ternary_r(&mut rng, k, d, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let wp = crate::drs::project_weights(&r, &w);
        for &g in &[0.0f32, 0.5, 0.9] {
            let out = dsg_layer(&x, &wt, &wp, &ridx, g);
            assert!(
                (out.density - (1.0 - g as f64)).abs() < 0.1,
                "gamma {g}: density {}",
                out.density
            );
        }
    }

    #[test]
    fn dsg_layer_gamma0_matches_dense() {
        let mut rng = Pcg32::seeded(54);
        let (m, d, n, k) = (8, 128, 32, 48);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let r = ternary_r(&mut rng, k, d, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let wp = crate::drs::project_weights(&r, &w);
        let out = dsg_layer(&x, &wt, &wp, &ridx, 0.0);
        let want = ops::matmul_naive(&x, &w);
        assert!(out.y.allclose(&want, 1e-3, 1e-3));
        assert_eq!(out.density, 1.0);
    }

    #[test]
    fn dsg_selected_values_are_exact() {
        // Where the mask is 1 the DSG output equals the dense product —
        // DRS only decides WHAT to compute, never approximates the value.
        let mut rng = Pcg32::seeded(55);
        let (m, d, n, k) = (16, 200, 40, 60);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let r = ternary_r(&mut rng, k, d, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let wp = crate::drs::project_weights(&r, &w);
        let out = dsg_layer(&x, &wt, &wp, &ridx, 0.7);
        let mask = out.mask.to_dense();
        let dense = ops::matmul_naive(&x, &w);
        for i in 0..m * n {
            if mask.data()[i] != 0.0 {
                assert!((out.y.data()[i] - dense.data()[i]).abs() < 1e-3);
            } else {
                assert_eq!(out.y.data()[i], 0.0);
            }
        }
    }
}
