//! Multi-threaded variants of the CPU engines (std::thread scoped —
//! rayon is unavailable offline).  Work is split by output rows; each
//! thread writes a disjoint slice, so no synchronization is needed
//! beyond the join.
//!
//! These back the §Perf optimization pass: the single-threaded engines
//! stay as the reference (and as the Fig 8a apples-to-apples baselines),
//! the parallel ones are what a deployment would run.

use crate::tensor::Tensor;

/// Number of worker threads (DSG_THREADS overrides; default = cores).
pub fn n_threads() -> usize {
    if let Ok(v) = std::env::var("DSG_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `rows` into at most `parts` contiguous chunks.
fn row_chunks(rows: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(rows).max(1);
    let base = rows / parts;
    let extra = rows % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Parallel blocked GEMM: x (m, k) * w (k, n).
pub fn matmul_parallel(x: &Tensor, w: &Tensor) -> Tensor {
    matmul_parallel_with(x, w, n_threads())
}

/// `matmul_parallel` with an explicit thread budget.  Results are
/// bit-exact for ANY budget: work splits by output rows and each output
/// element's accumulation order never changes — the serving layer relies
/// on this to keep predictions identical across worker counts.
pub fn matmul_parallel_with(x: &Tensor, w: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    let chunks = row_chunks(m, threads.max(1));
    let xd = x.data();
    let wd = w.data();
    std::thread::scope(|scope| {
        let mut remaining: &mut [f32] = &mut out;
        for &(lo, hi) in &chunks {
            let (mine, rest) = remaining.split_at_mut((hi - lo) * n);
            remaining = rest;
            scope.spawn(move || {
                const KC: usize = 256;
                for p0 in (0..k).step_by(KC) {
                    let p1 = (p0 + KC).min(k);
                    for i in lo..hi {
                        let arow = &xd[i * k..(i + 1) * k];
                        let orow = &mut mine[(i - lo) * n..(i - lo + 1) * n];
                        for p in p0..p1 {
                            let av = arow[p];
                            if av == 0.0 {
                                continue;
                            }
                            let brow = &wd[p * n..(p + 1) * n];
                            let mut j = 0;
                            while j + 4 <= n {
                                orow[j] += av * brow[j];
                                orow[j + 1] += av * brow[j + 1];
                                orow[j + 2] += av * brow[j + 2];
                                orow[j + 3] += av * brow[j + 3];
                                j += 4;
                            }
                            while j < n {
                                orow[j] += av * brow[j];
                                j += 1;
                            }
                        }
                    }
                }
            });
        }
    });
    Tensor::new(&[m, n], out)
}

/// Parallel DSG masked VMM over transposed weights wt (n, d).
pub fn dsg_vmm_parallel(x: &Tensor, wt: &Tensor, mask: &Tensor) -> Tensor {
    dsg_vmm_parallel_with(x, wt, mask, n_threads())
}

/// `dsg_vmm_parallel` with an explicit thread budget (bit-exact for any
/// budget — row split only, per-row op order unchanged).
pub fn dsg_vmm_parallel_with(x: &Tensor, wt: &Tensor, mask: &Tensor, threads: usize) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.shape(), &[m, n]);
    let mut out = vec![0.0f32; m * n];
    let chunks = row_chunks(m, threads.max(1));
    let xd = x.data();
    let wd = wt.data();
    let md = mask.data();
    std::thread::scope(|scope| {
        let mut remaining: &mut [f32] = &mut out;
        for &(lo, hi) in &chunks {
            let (mine, rest) = remaining.split_at_mut((hi - lo) * n);
            remaining = rest;
            scope.spawn(move || {
                for i in lo..hi {
                    let row = &xd[i * d..(i + 1) * d];
                    let mrow = &md[i * n..(i + 1) * n];
                    let orow = &mut mine[(i - lo) * n..(i - lo + 1) * n];
                    for j in 0..n {
                        if mrow[j] == 0.0 {
                            continue;
                        }
                        let wrow = &wd[j * d..(j + 1) * d];
                        let mut acc = 0.0f32;
                        let mut p = 0;
                        while p + 4 <= d {
                            acc += row[p] * wrow[p]
                                + row[p + 1] * wrow[p + 1]
                                + row[p + 2] * wrow[p + 2]
                                + row[p + 3] * wrow[p + 3];
                            p += 4;
                        }
                        while p < d {
                            acc += row[p] * wrow[p];
                            p += 1;
                        }
                        orow[j] = acc;
                    }
                }
            });
        }
    });
    Tensor::new(&[m, n], out)
}

/// Parallel row projection through a ternary index.
pub fn project_rows_parallel(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
) -> Tensor {
    project_rows_parallel_with(x, ridx, n_threads())
}

/// `project_rows_parallel` with an explicit thread budget (bit-exact
/// for any budget).
pub fn project_rows_parallel_with(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
    threads: usize,
) -> Tensor {
    let m = x.shape()[0];
    let k = ridx.k;
    let mut out = vec![0.0f32; m * k];
    let chunks = row_chunks(m, threads.max(1));
    let xd = x.data();
    std::thread::scope(|scope| {
        let mut remaining: &mut [f32] = &mut out;
        for &(lo, hi) in &chunks {
            let (mine, rest) = remaining.split_at_mut((hi - lo) * k);
            remaining = rest;
            scope.spawn(move || {
                for i in lo..hi {
                    ridx.project_row(
                        &xd[i * ridx.d..(i + 1) * ridx.d],
                        &mut mine[(i - lo) * k..(i - lo + 1) * k],
                    );
                }
            });
        }
    });
    Tensor::new(&[m, k], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drs::projection::{ternary_r, TernaryIndex};
    use crate::tensor::ops;
    use crate::util::Pcg32;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn row_chunks_cover_exactly() {
        for rows in [1usize, 5, 16, 100, 101] {
            for parts in [1usize, 2, 7, 16] {
                let ch = row_chunks(rows, parts);
                assert_eq!(ch[0].0, 0);
                assert_eq!(ch.last().unwrap().1, rows);
                for w in ch.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = Pcg32::seeded(61);
        let x = randn(&mut rng, &[37, 120]);
        let w = randn(&mut rng, &[120, 53]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_blocked(&x, &w);
        assert!(a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn parallel_dsg_vmm_matches_serial() {
        let mut rng = Pcg32::seeded(62);
        let x = randn(&mut rng, &[29, 64]);
        let w = randn(&mut rng, &[64, 31]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[29, 31], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        let a = dsg_vmm_parallel(&x, &wt, &mask);
        let b = crate::sparse::dsg_vmm(&x, &wt, &mask);
        assert_eq!(a, b); // identical op order per row => bit-exact
    }

    #[test]
    fn parallel_projection_matches_serial() {
        let mut rng = Pcg32::seeded(63);
        let x = randn(&mut rng, &[19, 96]);
        let r = ternary_r(&mut rng, 24, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let a = project_rows_parallel(&x, &ridx);
        let b = crate::drs::project_rows(&x, &r);
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_thread_budgets_are_bit_exact() {
        // The serving layer divides cores across workers, so the SAME
        // inputs must give the SAME bits under any thread budget.
        let mut rng = Pcg32::seeded(65);
        let x = randn(&mut rng, &[23, 96]);
        let w = randn(&mut rng, &[96, 41]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[23, 41], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let r = ternary_r(&mut rng, 16, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let mm1 = matmul_parallel_with(&x, &w, 1);
        let vm1 = dsg_vmm_parallel_with(&x, &wt, &mask, 1);
        let pr1 = project_rows_parallel_with(&x, &ridx, 1);
        for t in [2usize, 3, 8] {
            assert_eq!(mm1, matmul_parallel_with(&x, &w, t), "matmul @ {t}");
            assert_eq!(vm1, dsg_vmm_parallel_with(&x, &wt, &mask, t), "vmm @ {t}");
            assert_eq!(pr1, project_rows_parallel_with(&x, &ridx, t), "proj @ {t}");
        }
    }

    #[test]
    fn single_row_works() {
        let mut rng = Pcg32::seeded(64);
        let x = randn(&mut rng, &[1, 16]);
        let w = randn(&mut rng, &[16, 8]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_naive(&x, &w);
        assert!(a.allclose(&b, 1e-4, 1e-4));
    }
}
