//! Multi-threaded variants of the CPU engines, dispatched on the
//! persistent [`crate::sparse::pool::WorkerPool`] (rayon is unavailable
//! offline; the first perf pass used `std::thread::scope`, which
//! re-spawned OS threads per layer per request — the pool removes that).
//!
//! Work is split by output rows; each chunk writes a disjoint slice and
//! per-element accumulation order never changes, so results are bit-exact
//! for ANY thread budget — the invariant the serving layer relies on to
//! divide cores across workers freely.
//!
//! Layering:
//!
//! * `*_chunk` — slice-level serial kernels over a row range `[lo, hi)`
//!   writing the chunk's output slice.  Shared by the serial `_into`
//!   paths and the pool dispatch, so "parallel at budget 1" and "one
//!   chunk of a parallel run" are literally the same code.
//! * `*_parallel_into` — allocation-free entry points writing into
//!   caller-owned buffers (the [`crate::native::ForwardWorkspace`] hot
//!   path).
//! * `*_parallel[_with]` — Tensor-returning wrappers (compat + tests).

use super::pool::{Task, WorkerPool};
use crate::drs::topk::RowMask;
use crate::tensor::Tensor;
use std::sync::OnceLock;

/// Number of worker threads (`DSG_THREADS` overrides; default = cores).
/// Cached in a `OnceLock`: the env lookup happens once per process, and
/// the global pool is sized from the first answer.
pub fn n_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DSG_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split `rows` into at most `parts` contiguous chunks.
fn row_chunks(rows: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(rows).max(1);
    let base = rows / parts;
    let extra = rows % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(lo, hi, chunk)` over row chunks of `out` (rows x cols), one
/// chunk per thread-budget slot, on the global pool.  A budget of 1 (or
/// a single row) runs inline with zero dispatch overhead.
fn for_row_chunks<F>(threads: usize, rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Send + Sync,
{
    debug_assert_eq!(out.len(), rows * cols);
    let chunks = row_chunks(rows, threads.max(1));
    if chunks.len() <= 1 {
        f(0, rows, out);
        return;
    }
    let f = &f;
    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(chunks.len());
    let mut remaining: &mut [f32] = out;
    for &(lo, hi) in &chunks {
        let (mine, rest) = remaining.split_at_mut((hi - lo) * cols);
        remaining = rest;
        tasks.push(Box::new(move || f(lo, hi, mine)));
    }
    WorkerPool::global().run(tasks);
}

// ---------------------------------------------------------------------------
// slice kernels (row-range, serial)
// ---------------------------------------------------------------------------

/// Blocked saxpy GEMM rows `[lo, hi)` of x (m, k) * w (k, n) into the
/// chunk slice `out` (len (hi-lo)*n).  Zeroes `out` first.
pub fn matmul_chunk(xd: &[f32], wd: &[f32], k: usize, n: usize, lo: usize, hi: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    const KC: usize = 256;
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in lo..hi {
            let arow = &xd[i * k..(i + 1) * k];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for p in p0..p1 {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &wd[p * n..(p + 1) * n];
                let mut j = 0;
                while j + 4 <= n {
                    orow[j] += av * brow[j];
                    orow[j + 1] += av * brow[j + 1];
                    orow[j + 2] += av * brow[j + 2];
                    orow[j + 3] += av * brow[j + 3];
                    j += 4;
                }
                while j < n {
                    orow[j] += av * brow[j];
                    j += 1;
                }
            }
        }
    }
}

/// One masked-VMM dot product: row (len d) . wrow (len d), the exact
/// accumulation order every engine variant shares.
#[inline]
fn vmm_dot(row: &[f32], wrow: &[f32], d: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut p = 0;
    while p + 4 <= d {
        acc += row[p] * wrow[p]
            + row[p + 1] * wrow[p + 1]
            + row[p + 2] * wrow[p + 2]
            + row[p + 3] * wrow[p + 3];
        p += 4;
    }
    while p < d {
        acc += row[p] * wrow[p];
        p += 1;
    }
    acc
}

/// Dense-mask masked VMM rows `[lo, hi)` over transposed weights wt
/// (n, d), scanning all n mask entries per row (the pre-RowMask
/// baseline, kept for compat and as the bench comparison).  Zeroes the
/// chunk first.
#[allow(clippy::too_many_arguments)]
pub fn vmm_mask_chunk(
    xd: &[f32],
    wd: &[f32],
    md: &[f32],
    d: usize,
    n: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    for i in lo..hi {
        let row = &xd[i * d..(i + 1) * d];
        let mrow = &md[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
        for j in 0..n {
            if mrow[j] == 0.0 {
                continue;
            }
            orow[j] = vmm_dot(row, &wd[j * d..(j + 1) * d], d);
        }
    }
}

/// RowMask masked VMM rows `[lo, hi)`: jump straight to the selected
/// output neurons instead of branch-scanning all n columns.  Selected
/// indices are ascending, so the visit order — and therefore every bit
/// of the result — matches the dense-mask scan.  Zeroes the chunk
/// first; a full mask falls back to the dense row sweep (same op order,
/// no index indirection).
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_chunk(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    if mask.is_full() {
        // keep-all fast path (gamma = 0): every j in 0..n, same order
        for i in lo..hi {
            let row = &xd[i * d..(i + 1) * d];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                orow[j] = vmm_dot(row, &wd[j * d..(j + 1) * d], d);
            }
        }
        return;
    }
    out.fill(0.0);
    for i in lo..hi {
        let row = &xd[i * d..(i + 1) * d];
        let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
        for &j in mask.row(i) {
            let j = j as usize;
            orow[j] = vmm_dot(row, &wd[j * d..(j + 1) * d], d);
        }
    }
}

/// Backward-to-input of the RowMask VMM, rows `[lo, hi)`:
/// dx_i = sum_{j in mask.row(i)} dy[i, j] * wt[j, :] over transposed
/// weights wt (n, d).  Only the SELECTED gradient entries are read —
/// Algorithm 1's forced gradient sparsification falls out structurally
/// (unselected dy values never touch the accumulators).  Zeroes the
/// chunk first; a full mask sweeps every j in the same ascending order,
/// so gamma = 0 is bit-identical to a dense dY * W^T.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_backward_chunk(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    out.fill(0.0);
    if mask.is_full() {
        // keep-all fast path (gamma = 0 / dense mode): sweep every j in
        // the same ascending order, no index indirection — bit-identical
        for i in lo..hi {
            let dyrow = &dyd[i * n..(i + 1) * n];
            let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
            for (j, &g) in dyrow.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                let wrow = &wd[j * d..(j + 1) * d];
                for p in 0..d {
                    orow[p] += g * wrow[p];
                }
            }
        }
        return;
    }
    for i in lo..hi {
        let dyrow = &dyd[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
        for &j in mask.row(i) {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue; // relu'd-away entries: same skip rule as matmul_chunk
            }
            let wrow = &wd[j * d..(j + 1) * d];
            for p in 0..d {
                orow[p] += g * wrow[p];
            }
        }
    }
}

/// Backward-to-weights of the RowMask VMM for OUTPUT NEURONS `[jlo, jhi)`:
/// dwt[j, :] = sum_i [j in mask.row(i)] dy[i, j] * x[i, :], written into
/// the chunk slice (len (jhi-jlo)*d) of the transposed-layout gradient
/// dwt (n, d).  The split is by output neuron, so each dwt row is
/// accumulated by exactly one chunk in fixed ascending-i order —
/// bit-exact for any thread budget, like the forward engines.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_gradw_chunk(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (jhi - jlo) * d);
    out.fill(0.0);
    if mask.is_full() {
        // keep-all fast path: same i-outer / ascending-j-inner order as
        // the selected walk below, minus the index list + searches
        for i in 0..m {
            let xrow = &xd[i * d..(i + 1) * d];
            let dyrow = &dyd[i * n..(i + 1) * n];
            for j in jlo..jhi {
                let g = dyrow[j];
                if g == 0.0 {
                    continue;
                }
                let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
                for p in 0..d {
                    orow[p] += g * xrow[p];
                }
            }
        }
        return;
    }
    for i in 0..m {
        let xrow = &xd[i * d..(i + 1) * d];
        let dyrow = &dyd[i * n..(i + 1) * n];
        let sel = mask.row(i);
        // selected indices are ascending: binary-search the [jlo, jhi) span
        let a = sel.partition_point(|&j| (j as usize) < jlo);
        let b = sel.partition_point(|&j| (j as usize) < jhi);
        for &j in &sel[a..b] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
            for p in 0..d {
                orow[p] += g * xrow[p];
            }
        }
    }
}

/// Ternary projection of rows `[lo, hi)` into the chunk slice.
pub fn project_chunk(
    ridx: &crate::drs::projection::TernaryIndex,
    xd: &[f32],
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * ridx.k);
    for i in lo..hi {
        ridx.project_row(
            &xd[i * ridx.d..(i + 1) * ridx.d],
            &mut out[(i - lo) * ridx.k..(i - lo + 1) * ridx.k],
        );
    }
}

// ---------------------------------------------------------------------------
// allocation-free entry points
// ---------------------------------------------------------------------------

/// Pool-parallel GEMM x (m, k) * w (k, n) into `out` (len m*n).
pub fn matmul_parallel_into(
    xd: &[f32],
    m: usize,
    k: usize,
    wd: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * k);
    debug_assert_eq!(wd.len(), k * n);
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        matmul_chunk(xd, wd, k, n, lo, hi, chunk)
    });
}

/// Pool-parallel dense-mask VMM into `out` (len m*n).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_parallel_into(
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    md: &[f32],
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(wd.len(), n * d);
    debug_assert_eq!(md.len(), m * n);
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        vmm_mask_chunk(xd, wd, md, d, n, lo, hi, chunk)
    });
}

/// Pool-parallel RowMask VMM into `out` (len m*n).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_parallel_into(
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        vmm_rowmask_chunk(xd, wd, d, n, mask, lo, hi, chunk)
    });
}

/// Pool-parallel backward-to-input of the RowMask VMM into `out`
/// (len m*d): dX = (masked dY) @ W, reading only selected gradients.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_backward_parallel_into(
    dyd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(dyd.len(), m * n);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    for_row_chunks(threads, m, d, out, |lo, hi, chunk| {
        vmm_rowmask_backward_chunk(dyd, wd, d, n, mask, lo, hi, chunk)
    });
}

/// Pool-parallel backward-to-weights of the RowMask VMM into the
/// transposed-layout gradient `out` (len n*d), split by output neuron.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_gradw_parallel_into(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(dyd.len(), m * n);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    for_row_chunks(threads, n, d, out, |jlo, jhi, chunk| {
        vmm_rowmask_gradw_chunk(xd, dyd, m, d, n, mask, jlo, jhi, chunk)
    });
}

/// Pool-parallel ternary projection into `out` (len m*k).
pub fn project_rows_parallel_into(
    xd: &[f32],
    m: usize,
    ridx: &crate::drs::projection::TernaryIndex,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * ridx.d);
    for_row_chunks(threads, m, ridx.k, out, |lo, hi, chunk| {
        project_chunk(ridx, xd, lo, hi, chunk)
    });
}

// ---------------------------------------------------------------------------
// Tensor wrappers
// ---------------------------------------------------------------------------

/// Parallel blocked GEMM: x (m, k) * w (k, n).
pub fn matmul_parallel(x: &Tensor, w: &Tensor) -> Tensor {
    matmul_parallel_with(x, w, n_threads())
}

/// `matmul_parallel` with an explicit thread budget.  Results are
/// bit-exact for ANY budget: work splits by output rows and each output
/// element's accumulation order never changes — the serving layer relies
/// on this to keep predictions identical across worker counts.
pub fn matmul_parallel_with(x: &Tensor, w: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    matmul_parallel_into(x.data(), m, k, w.data(), n, threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel DSG masked VMM over transposed weights wt (n, d), dense f32
/// mask (m, n).
pub fn dsg_vmm_parallel(x: &Tensor, wt: &Tensor, mask: &Tensor) -> Tensor {
    dsg_vmm_parallel_with(x, wt, mask, n_threads())
}

/// `dsg_vmm_parallel` with an explicit thread budget (bit-exact for any
/// budget — row split only, per-row op order unchanged).
pub fn dsg_vmm_parallel_with(x: &Tensor, wt: &Tensor, mask: &Tensor, threads: usize) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.shape(), &[m, n]);
    let mut out = vec![0.0f32; m * n];
    dsg_vmm_parallel_into(x.data(), m, d, wt.data(), n, mask.data(), threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel DSG masked VMM over a compact [`RowMask`].
pub fn dsg_vmm_rowmask_parallel(x: &Tensor, wt: &Tensor, mask: &RowMask) -> Tensor {
    dsg_vmm_rowmask_parallel_with(x, wt, mask, n_threads())
}

/// `dsg_vmm_rowmask_parallel` with an explicit thread budget.  Bit-exact
/// with the dense-mask engine for the same selection, and across
/// budgets.
pub fn dsg_vmm_rowmask_parallel_with(
    x: &Tensor,
    wt: &Tensor,
    mask: &RowMask,
    threads: usize,
) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    let mut out = vec![0.0f32; m * n];
    dsg_vmm_rowmask_parallel_into(x.data(), m, d, wt.data(), n, mask, threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel row projection through a ternary index.
pub fn project_rows_parallel(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
) -> Tensor {
    project_rows_parallel_with(x, ridx, n_threads())
}

/// `project_rows_parallel` with an explicit thread budget (bit-exact
/// for any budget).
pub fn project_rows_parallel_with(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
    threads: usize,
) -> Tensor {
    let m = x.shape()[0];
    let mut out = vec![0.0f32; m * ridx.k];
    project_rows_parallel_into(x.data(), m, ridx, threads, &mut out);
    Tensor::new(&[m, ridx.k], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drs::projection::{ternary_r, TernaryIndex};
    use crate::tensor::ops;
    use crate::util::Pcg32;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn row_chunks_cover_exactly() {
        for rows in [1usize, 5, 16, 100, 101] {
            for parts in [1usize, 2, 7, 16] {
                let ch = row_chunks(rows, parts);
                assert_eq!(ch[0].0, 0);
                assert_eq!(ch.last().unwrap().1, rows);
                for w in ch.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = Pcg32::seeded(61);
        let x = randn(&mut rng, &[37, 120]);
        let w = randn(&mut rng, &[120, 53]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_blocked(&x, &w);
        assert!(a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn parallel_dsg_vmm_matches_serial() {
        let mut rng = Pcg32::seeded(62);
        let x = randn(&mut rng, &[29, 64]);
        let w = randn(&mut rng, &[64, 31]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[29, 31], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        let a = dsg_vmm_parallel(&x, &wt, &mask);
        let b = crate::sparse::dsg_vmm(&x, &wt, &mask);
        assert_eq!(a, b); // identical op order per row => bit-exact
    }

    #[test]
    fn rowmask_vmm_matches_dense_mask_vmm() {
        let mut rng = Pcg32::seeded(66);
        let x = randn(&mut rng, &[29, 64]);
        let w = randn(&mut rng, &[64, 31]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[29, 31], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        for t in [1usize, 3] {
            let dense = dsg_vmm_parallel_with(&x, &wt, &mask, t);
            let compact = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t);
            assert_eq!(dense, compact, "threads {t}");
        }
    }

    #[test]
    fn parallel_projection_matches_serial() {
        let mut rng = Pcg32::seeded(63);
        let x = randn(&mut rng, &[19, 96]);
        let r = ternary_r(&mut rng, 24, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let a = project_rows_parallel(&x, &ridx);
        let b = crate::drs::project_rows(&x, &r);
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_thread_budgets_are_bit_exact() {
        // The serving layer divides cores across workers, so the SAME
        // inputs must give the SAME bits under any thread budget.
        let mut rng = Pcg32::seeded(65);
        let x = randn(&mut rng, &[23, 96]);
        let w = randn(&mut rng, &[96, 41]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[23, 41], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        let r = ternary_r(&mut rng, 16, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let mm1 = matmul_parallel_with(&x, &w, 1);
        let vm1 = dsg_vmm_parallel_with(&x, &wt, &mask, 1);
        let rm1 = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, 1);
        let pr1 = project_rows_parallel_with(&x, &ridx, 1);
        for t in [2usize, 3, 8] {
            assert_eq!(mm1, matmul_parallel_with(&x, &w, t), "matmul @ {t}");
            assert_eq!(vm1, dsg_vmm_parallel_with(&x, &wt, &mask, t), "vmm @ {t}");
            assert_eq!(rm1, dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t), "rowmask @ {t}");
            assert_eq!(pr1, project_rows_parallel_with(&x, &ridx, t), "proj @ {t}");
        }
    }

    /// Reference backward-to-input: dX = (dY * dense mask) @ W.
    fn backward_input_reference(dy: &Tensor, w: &Tensor, mask: &Tensor) -> Tensor {
        let masked = Tensor::from_fn(dy.shape(), |i| dy.data()[i] * mask.data()[i]);
        ops::matmul_naive(&masked, w)
    }

    /// Reference backward-to-weights: dW^T = (dY * mask)^T @ X, (n, d).
    fn gradw_reference(x: &Tensor, dy: &Tensor, mask: &Tensor) -> Tensor {
        let masked = Tensor::from_fn(dy.shape(), |i| dy.data()[i] * mask.data()[i]);
        ops::matmul_naive(&ops::transpose(&masked), x)
    }

    #[test]
    fn rowmask_backward_matches_dense_reference() {
        let mut rng = Pcg32::seeded(71);
        let (m, d, n) = (13, 40, 21);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let dy = randn(&mut rng, &[m, n]);
        for frac in [0usize, 3, 1] {
            // frac 0 = empty-ish, 3 = quarter, 1 = full mask
            let mask = Tensor::from_fn(&[m, n], |i| if frac == 0 { 0.0 } else if i % frac == 0 { 1.0 } else { 0.0 });
            let rm = RowMask::from_dense(&mask);
            let want_dx = backward_input_reference(&dy, &w, &mask);
            let want_dwt = gradw_reference(&x, &dy, &mask);
            let mut dx = vec![f32::NAN; m * d];
            let mut dwt = vec![f32::NAN; n * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, 1, &mut dx);
            dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, 1, &mut dwt);
            let dx_t = Tensor::new(&[m, d], dx);
            let dwt_t = Tensor::new(&[n, d], dwt);
            assert!(dx_t.allclose(&want_dx, 1e-4, 1e-4), "dx frac {frac}");
            assert!(dwt_t.allclose(&want_dwt, 1e-4, 1e-4), "dwt frac {frac}");
        }
    }

    #[test]
    fn backward_kernels_bit_exact_across_budgets() {
        let mut rng = Pcg32::seeded(72);
        let (m, d, n) = (17, 48, 33);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let dy = randn(&mut rng, &[m, n]);
        let mask = Tensor::from_fn(&[m, n], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        let mut dx1 = vec![0.0f32; m * d];
        let mut dwt1 = vec![0.0f32; n * d];
        dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, 1, &mut dx1);
        dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, 1, &mut dwt1);
        for t in [2usize, 3, 8] {
            let mut dx = vec![0.0f32; m * d];
            let mut dwt = vec![0.0f32; n * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, t, &mut dx);
            dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, t, &mut dwt);
            assert_eq!(dx1, dx, "backward @ {t}");
            assert_eq!(dwt1, dwt, "gradw @ {t}");
        }
    }

    #[test]
    fn single_row_works() {
        let mut rng = Pcg32::seeded(64);
        let x = randn(&mut rng, &[1, 16]);
        let w = randn(&mut rng, &[16, 8]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_naive(&x, &w);
        assert!(a.allclose(&b, 1e-4, 1e-4));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        // steady-state: the same output buffer survives repeated calls
        let mut rng = Pcg32::seeded(67);
        let x = randn(&mut rng, &[9, 32]);
        let w = randn(&mut rng, &[32, 11]);
        let want = matmul_parallel_with(&x, &w, 2);
        let mut out = vec![f32::NAN; 9 * 11];
        for _ in 0..3 {
            matmul_parallel_into(x.data(), 9, 32, w.data(), 11, 2, &mut out);
            assert_eq!(out, want.data());
        }
    }
}
