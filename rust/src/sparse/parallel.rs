//! Multi-threaded variants of the CPU engines, dispatched on the
//! persistent [`crate::sparse::pool::WorkerPool`] (rayon is unavailable
//! offline; the first perf pass used `std::thread::scope`, which
//! re-spawned OS threads per layer per request — the pool removes that).
//!
//! Work is split by output rows; each chunk writes a disjoint slice and
//! per-element accumulation order never changes, so results are bit-exact
//! for ANY thread budget — the invariant the serving layer relies on to
//! divide cores across workers freely.
//!
//! Layering:
//!
//! * `*_chunk` — slice-level serial kernels over a row range `[lo, hi)`
//!   writing the chunk's output slice.  Shared by the serial `_into`
//!   paths and the pool dispatch, so "parallel at budget 1" and "one
//!   chunk of a parallel run" are literally the same code.
//! * `*_parallel_into` — allocation-free entry points writing into
//!   caller-owned buffers (the [`crate::native::ForwardWorkspace`] hot
//!   path).
//! * `*_parallel[_with]` — Tensor-returning wrappers (compat + tests).

use super::pool::{Task, WorkerPool};
use super::simd::{self, Isa, Prims};
use crate::drs::topk::RowMask;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Parse a raw `DSG_THREADS` value against the machine's core count.
/// Pure so the rejection rules are unit-testable without touching
/// process env: returns the budget plus an optional diagnostic naming
/// the variable and the fallback actually used.
fn threads_from_env(raw: Option<&str>, cores: usize) -> (usize, Option<String>) {
    let Some(raw) = raw else { return (cores, None) };
    match raw.parse::<usize>() {
        Ok(0) => (1, Some("DSG_THREADS=0 is not a valid budget; using 1 thread".to_string())),
        Ok(n) => (n, None),
        Err(_) => (
            cores,
            Some(format!(
                "DSG_THREADS={raw:?} is not a thread count; using {cores} (available cores)"
            )),
        ),
    }
}

/// Number of worker threads (`DSG_THREADS` overrides; default = cores).
/// Cached in a `OnceLock`: the env lookup happens once per process, and
/// the global pool is sized from the first answer.  An invalid override
/// is rejected with a one-time stderr warning (it used to be silently
/// ignored, leaving misconfigured deployments undiagnosable).
pub fn n_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let (n, warning) = threads_from_env(std::env::var("DSG_THREADS").ok().as_deref(), cores);
        if let Some(w) = warning {
            crate::warn!("{w}");
        }
        n
    })
}

/// Split `rows` into at most `parts` contiguous chunks.
fn row_chunks(rows: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(rows).max(1);
    let base = rows / parts;
    let extra = rows % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(lo, hi, chunk)` over row chunks of `out` (rows x cols), one
/// chunk per thread-budget slot, on the global pool.  A budget of 1 (or
/// a single row) runs inline with zero dispatch overhead.
fn for_row_chunks<F>(threads: usize, rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Send + Sync,
{
    debug_assert_eq!(out.len(), rows * cols);
    let chunks = row_chunks(rows, threads.max(1));
    if chunks.len() <= 1 {
        f(0, rows, out);
        return;
    }
    let f = &f;
    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(chunks.len());
    let mut remaining: &mut [f32] = out;
    for &(lo, hi) in &chunks {
        let (mine, rest) = remaining.split_at_mut((hi - lo) * cols);
        remaining = rest;
        tasks.push(Box::new(move || f(lo, hi, mine)));
    }
    WorkerPool::global().run(tasks);
}

// ---------------------------------------------------------------------------
// slice kernels (row-range, serial)
// ---------------------------------------------------------------------------

/// Blocked saxpy GEMM rows `[lo, hi)` of x (m, k) * w (k, n) into the
/// chunk slice `out` (len (hi-lo)*n).  Zeroes `out` first.
pub fn matmul_chunk(xd: &[f32], wd: &[f32], k: usize, n: usize, lo: usize, hi: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    const KC: usize = 256;
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in lo..hi {
            let arow = &xd[i * k..(i + 1) * k];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for p in p0..p1 {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &wd[p * n..(p + 1) * n];
                let mut j = 0;
                while j + 4 <= n {
                    orow[j] += av * brow[j];
                    orow[j + 1] += av * brow[j + 1];
                    orow[j + 2] += av * brow[j + 2];
                    orow[j + 3] += av * brow[j + 3];
                    j += 4;
                }
                while j < n {
                    orow[j] += av * brow[j];
                    j += 1;
                }
            }
        }
    }
}

/// One masked-VMM dot product: row (len d) . wrow (len d), the exact
/// accumulation order every engine variant shares.
#[inline]
fn vmm_dot(row: &[f32], wrow: &[f32], d: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut p = 0;
    while p + 4 <= d {
        acc += row[p] * wrow[p]
            + row[p + 1] * wrow[p + 1]
            + row[p + 2] * wrow[p + 2]
            + row[p + 3] * wrow[p + 3];
        p += 4;
    }
    while p < d {
        acc += row[p] * wrow[p];
        p += 1;
    }
    acc
}

/// Dense-mask masked VMM rows `[lo, hi)` over transposed weights wt
/// (n, d), scanning all n mask entries per row (the pre-RowMask
/// baseline, kept for compat and as the bench comparison).  Zeroes the
/// chunk first.
#[allow(clippy::too_many_arguments)]
pub fn vmm_mask_chunk(
    xd: &[f32],
    wd: &[f32],
    md: &[f32],
    d: usize,
    n: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    for i in lo..hi {
        let row = &xd[i * d..(i + 1) * d];
        let mrow = &md[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
        for j in 0..n {
            if mrow[j] == 0.0 {
                continue;
            }
            orow[j] = vmm_dot(row, &wd[j * d..(j + 1) * d], d);
        }
    }
}

/// RowMask masked VMM rows `[lo, hi)`: jump straight to the selected
/// output neurons instead of branch-scanning all n columns.  Selected
/// indices are ascending, so the visit order — and therefore every bit
/// of the result — matches the dense-mask scan.  Zeroes the chunk
/// first; a full mask falls back to the dense row sweep (same op order,
/// no index indirection).
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_chunk(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    vmm_rowmask_chunk_p::<ScalarPrims>(xd, wd, d, n, mask, lo, hi, out)
}

/// [`vmm_rowmask_chunk`] generic over the primitive set — the
/// monomorphized variants the [`KernelTable`] dispatch points at.
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_chunk_p<P: Prims>(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    if mask.is_full() {
        // keep-all fast path (gamma = 0): every j in 0..n, same order
        for i in lo..hi {
            let row = &xd[i * d..(i + 1) * d];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                orow[j] = P::dot(row, &wd[j * d..(j + 1) * d], d);
            }
        }
        return;
    }
    out.fill(0.0);
    for i in lo..hi {
        let row = &xd[i * d..(i + 1) * d];
        let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
        for &j in mask.row(i) {
            let j = j as usize;
            orow[j] = P::dot(row, &wd[j * d..(j + 1) * d], d);
        }
    }
}

/// Backward-to-input of the RowMask VMM, rows `[lo, hi)`:
/// dx_i = sum_{j in mask.row(i)} dy[i, j] * wt[j, :] over transposed
/// weights wt (n, d).  Only the SELECTED gradient entries are read —
/// Algorithm 1's forced gradient sparsification falls out structurally
/// (unselected dy values never touch the accumulators).  Zeroes the
/// chunk first; a full mask sweeps every j in the same ascending order,
/// so gamma = 0 is bit-identical to a dense dY * W^T.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_backward_chunk(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    vmm_rowmask_backward_chunk_p::<ScalarPrims>(dyd, wd, d, n, mask, lo, hi, out)
}

/// [`vmm_rowmask_backward_chunk`] generic over the primitive set.  The
/// inner accumulate goes through `P::axpy` — independent slots, so the
/// unroll/vector width cannot change bits.
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_backward_chunk_p<P: Prims>(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    out.fill(0.0);
    if mask.is_full() {
        // keep-all fast path (gamma = 0 / dense mode): sweep every j in
        // the same ascending order, no index indirection — bit-identical
        for i in lo..hi {
            let dyrow = &dyd[i * n..(i + 1) * n];
            let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
            for (j, &g) in dyrow.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
            }
        }
        return;
    }
    for i in lo..hi {
        let dyrow = &dyd[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
        for &j in mask.row(i) {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue; // relu'd-away entries: same skip rule as matmul_chunk
            }
            P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
        }
    }
}

/// Backward-to-weights of the RowMask VMM for OUTPUT NEURONS `[jlo, jhi)`:
/// dwt[j, :] = sum_i [j in mask.row(i)] dy[i, j] * x[i, :], written into
/// the chunk slice (len (jhi-jlo)*d) of the transposed-layout gradient
/// dwt (n, d).  The split is by output neuron, so each dwt row is
/// accumulated by exactly one chunk in fixed ascending-i order —
/// bit-exact for any thread budget, like the forward engines.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_gradw_chunk(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) {
    vmm_rowmask_gradw_chunk_p::<ScalarPrims>(xd, dyd, m, d, n, mask, jlo, jhi, out)
}

/// [`vmm_rowmask_gradw_chunk`] generic over the primitive set (the axpy
/// accumulate has independent slots — same bits at any vector width).
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_gradw_chunk_p<P: Prims>(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (jhi - jlo) * d);
    out.fill(0.0);
    if mask.is_full() {
        // keep-all fast path: same i-outer / ascending-j-inner order as
        // the selected walk below, minus the index list + searches
        for i in 0..m {
            let xrow = &xd[i * d..(i + 1) * d];
            let dyrow = &dyd[i * n..(i + 1) * n];
            for j in jlo..jhi {
                let g = dyrow[j];
                if g == 0.0 {
                    continue;
                }
                let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
                P::axpy(orow, g, xrow);
            }
        }
        return;
    }
    for i in 0..m {
        let xrow = &xd[i * d..(i + 1) * d];
        let dyrow = &dyd[i * n..(i + 1) * n];
        let sel = mask.row(i);
        // selected indices are ascending: binary-search the [jlo, jhi) span
        let a = sel.partition_point(|&j| (j as usize) < jlo);
        let b = sel.partition_point(|&j| (j as usize) < jhi);
        for &j in &sel[a..b] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
            P::axpy(orow, g, xrow);
        }
    }
}

// ---------------------------------------------------------------------------
// packed-gather kernels (FixedK structured masks)
// ---------------------------------------------------------------------------
//
// A structured (constant fan-in) RowMask stores one contiguous rows x k
// index matrix (`RowMask::packed`): no offsets array, no per-row length.
// These variants exploit that regularity — the selection loop has a
// FIXED trip count k, row i's indices are addressed directly at
// idx[i*k..(i+1)*k] (one multiply instead of two offset loads), and the
// gradW span search binary-searches a k-length row.  Each packed kernel
// is bit-identical to its CSR twin on the same selection: it visits the
// same ascending indices with the same vmm_dot / vmm_dot_sparse
// accumulation grouping.  Layout moves loads and branches, never bits.
// The parallel entry points dispatch on `RowMask::packed()`, so every
// consumer gets the packed path for free when the selection is
// structured.

/// Packed-gather forward for a FixedK mask, rows `[lo, hi)`: the twin of
/// [`vmm_rowmask_chunk`] with a fixed k-trip selection loop over the
/// contiguous index matrix.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_chunk(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    vmm_fixedk_chunk_p::<ScalarPrims>(xd, wd, d, n, idx, k, lo, hi, out)
}

/// [`vmm_fixedk_chunk`] generic over the primitive set — the fixed
/// k-trip count is exactly what lets the SIMD dot run back-to-back with
/// no per-row branching.
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_chunk_p<P: Prims>(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    for i in lo..hi {
        let row = &xd[i * d..(i + 1) * d];
        let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
        for &j in &idx[i * k..(i + 1) * k] {
            let j = j as usize;
            orow[j] = P::dot(row, &wd[j * d..(j + 1) * d], d);
        }
    }
}

/// Packed-gather backward-to-input for a FixedK mask, rows `[lo, hi)`:
/// the twin of [`vmm_rowmask_backward_chunk`]'s selected walk.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_backward_chunk(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    vmm_fixedk_backward_chunk_p::<ScalarPrims>(dyd, wd, d, n, idx, k, lo, hi, out)
}

/// [`vmm_fixedk_backward_chunk`] generic over the primitive set.
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_backward_chunk_p<P: Prims>(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    out.fill(0.0);
    for i in lo..hi {
        let dyrow = &dyd[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
        for &j in &idx[i * k..(i + 1) * k] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue; // same skip rule as the CSR twin
            }
            P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
        }
    }
}

/// Packed-gather backward-to-weights for a FixedK mask, OUTPUT NEURONS
/// `[jlo, jhi)`: the twin of [`vmm_rowmask_gradw_chunk`]'s selected
/// walk — the span search runs over each row's fixed-k index slice.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_gradw_chunk(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) {
    vmm_fixedk_gradw_chunk_p::<ScalarPrims>(xd, dyd, m, d, n, idx, k, jlo, jhi, out)
}

/// [`vmm_fixedk_gradw_chunk`] generic over the primitive set.
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_gradw_chunk_p<P: Prims>(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (jhi - jlo) * d);
    out.fill(0.0);
    for i in 0..m {
        let xrow = &xd[i * d..(i + 1) * d];
        let dyrow = &dyd[i * n..(i + 1) * n];
        let sel = &idx[i * k..(i + 1) * k];
        let a = sel.partition_point(|&j| (j as usize) < jlo);
        let b = sel.partition_point(|&j| (j as usize) < jhi);
        for &j in &sel[a..b] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
            P::axpy(orow, g, xrow);
        }
    }
}

// ---------------------------------------------------------------------------
// compound-sparsity kernels (input AND output side)
// ---------------------------------------------------------------------------
//
// The paper's Fig 8/9 operation reduction is (1 - gamma)^2: the graph is
// sparse on BOTH sides of a layer — inputs carry the previous layer's
// mask + ReLU zeros, outputs are restricted to the DRS selection.  The
// kernels above only exploit the output side (each selected neuron still
// streams the full d-length input row), so realized work scaled as
// (1 - gamma).  The kernels below gather the nonzero input coordinates
// once per row and accumulate `x[q] * w[q, j]` over ascending q into
// only the selected outputs: ops proportional to nnz(in) * sel(out).
//
// BIT-EXACTNESS CONTRACT: `vmm_dot` accumulates 4-aligned blocks
// (left-associated within a block) in ascending-q order, plus a
// sequential tail.  `vmm_dot_sparse` reproduces that exact grouping over
// the nonzero coordinates only.  Skipping a `x[q] == 0.0` term is a
// bit-identity because (a) its product is ±0.0 for finite weights,
// (b) adding ±0.0 never changes a nonzero partial, and (c) no
// accumulator that starts at +0.0 can ever become -0.0 under
// round-to-nearest addition — the same argument `matmul_chunk` already
// relies on for its `av == 0.0` skip.  (Weights must be finite: a
// 0 * inf/NaN term would be skipped where the dense walk propagates
// NaN.  The repo-wide zero-skip contract already assumes this.)
//
// DENSITY DISPATCH: which side pays is decided at two levels.  Per
// LAYER, callers pass the measured density of the input activation
// (previous layer's mask density, adjusted for ReLU/BN — see the native
// engine); at or above [`compound_cutoff`] the entry routes to the
// plain output-sparse kernel and never gathers.  Per ROW, the gathered
// nnz count double-checks the hint: a dense row inside a sparse layer
// takes the contiguous `vmm_dot` sweep, a sparse row the indexed
// accumulate.  Every branch is bit-identical, so dispatch is purely a
// performance decision and wrong hints cannot change results.

/// Which sparse kernels a configurable engine routes through — the
/// output-sparse-only kernels this repo shipped first, the
/// compound-sparsity kernels, or the compound kernels over
/// runtime-detected SIMD primitives.  `OutputSparse` and `Compound` are
/// bit-identical by construction (baseline/bench/parity knobs); `Simd`
/// is the ONE relaxed mode — its forward dot products may differ from
/// the scalar contract by a bounded ULP count (see
/// `docs/ARCHITECTURE.md`), which is why it must be explicitly opted
/// into and is never the default.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SparseKernels {
    /// Output-side skipping only (`vmm_rowmask_chunk` & friends).
    OutputSparse,
    /// Input- AND output-side skipping (the compound kernels).
    #[default]
    Compound,
    /// The compound kernels over the [`active_kernels`] dispatch table:
    /// AVX2/FMA where the runtime probe passes, bit-exact scalar
    /// fallback everywhere else (including under `DSG_SIMD=off`).
    Simd,
}

impl SparseKernels {
    pub fn parse(s: &str) -> Option<SparseKernels> {
        match s {
            "output" | "output-sparse" => Some(SparseKernels::OutputSparse),
            "compound" => Some(SparseKernels::Compound),
            "simd" => Some(SparseKernels::Simd),
            _ => None,
        }
    }

    /// The dispatch table this kernel family runs on: the ISA-selected
    /// table for `Simd`, the scalar table (today's exact code) for
    /// everything else.
    pub fn table(self) -> &'static KernelTable {
        match self {
            SparseKernels::Simd => active_kernels(),
            _ => scalar_kernels(),
        }
    }
}

/// Estimated nonzero density of a masked layer's OUTPUT — the next
/// layer's compound-dispatch hint, derived from the measured mask
/// density.  ONE shared rule for every engine (inference, training,
/// synth serving), so their dispatch never drifts apart:
///
/// * with BN, the relu zeros of SELECTED neurons are shifted back to
///   nonzero — under the double mask the remaining zeros are exactly
///   the unselected set (density = mask density); without the double
///   mask BN revives everything (density = 1.0);
/// * without BN nothing revives the zeros: unselected outputs stay 0
///   (they were never computed) and relu kills about half the
///   survivors, double mask or not (density = 0.5 * mask density).
///
/// Unmasked dense layers pass `mask_density = 1.0`.  Hints only steer
/// dispatch — every branch is bit-identical — so the estimate needs to
/// be consistent, not perfect.
pub fn density_hint_after_layer(mask_density: f32, use_bn: bool, double_mask: bool) -> f32 {
    if use_bn {
        if double_mask {
            mask_density
        } else {
            1.0
        }
    } else {
        0.5 * mask_density
    }
}

/// Count of (row, selected-j) pairs with a nonzero gradient — the
/// entries the masked backward kernels actually touch (both families
/// skip `g == 0.0`).  Used to report MEASURED realized ops for the
/// branches whose kernels don't count internally.
pub fn live_grad_count(dyd: &[f32], n: usize, mask: &RowMask) -> u64 {
    let mut live = 0u64;
    for i in 0..mask.rows() {
        let dyrow = &dyd[i * n..(i + 1) * n];
        if mask.is_full() {
            live += dyrow.iter().filter(|g| **g != 0.0).count() as u64;
        } else {
            live += mask.row(i).iter().filter(|&&j| dyrow[j as usize] != 0.0).count() as u64;
        }
    }
    live
}

/// Parse a raw `DSG_COMPOUND_CUTOFF` value.  Non-finite parses are
/// REJECTED, not clamped: `f32::clamp` passes NaN through, and a NaN
/// cutoff makes every `density >= cutoff` comparison false — silently
/// forcing the gather path everywhere (the bug this helper fixes).
/// Pure so the rejection rules are unit-testable; returns the cutoff
/// plus an optional diagnostic naming the variable and the fallback.
fn cutoff_from_env(raw: Option<&str>) -> (f32, Option<String>) {
    const DEFAULT: f32 = 0.5;
    let Some(raw) = raw else { return (DEFAULT, None) };
    match raw.parse::<f32>() {
        Ok(v) if v.is_finite() => (v.clamp(0.0, 1.0), None),
        Ok(_) => (
            DEFAULT,
            Some(format!(
                "DSG_COMPOUND_CUTOFF={raw:?} is not finite; using {DEFAULT}"
            )),
        ),
        Err(_) => (
            DEFAULT,
            Some(format!(
                "DSG_COMPOUND_CUTOFF={raw:?} is not a density; using {DEFAULT}"
            )),
        ),
    }
}

/// Input-density cutoff for the compound dispatch (`DSG_COMPOUND_CUTOFF`
/// overrides; default 0.5): at or above this nonzero fraction the
/// contiguous dense sweep wins over indexed accumulation, below it the
/// gather pays for itself.  Cached once per process like `n_threads`;
/// invalid or non-finite overrides fall back to 0.5 with a one-time
/// stderr warning.
pub fn compound_cutoff() -> f32 {
    static C: OnceLock<f32> = OnceLock::new();
    *C.get_or_init(|| {
        let (c, warning) =
            cutoff_from_env(std::env::var("DSG_COMPOUND_CUTOFF").ok().as_deref());
        if let Some(w) = warning {
            crate::warn!("{w}");
        }
        c
    })
}

/// Per-thread nonzero-gather scratch.  Pool workers are persistent, so
/// after warmup no compound dispatch allocates: each thread reuses one
/// index buffer across rows, layers, and requests.
fn with_nz_scratch<R>(f: impl FnOnce(&mut Vec<u32>) -> R) -> R {
    thread_local! {
        static NZ: std::cell::RefCell<Vec<u32>> = std::cell::RefCell::new(Vec::new());
    }
    NZ.with(|c| f(&mut c.borrow_mut()))
}

/// Gather the (ascending) nonzero coordinates of one input row.
#[inline]
fn gather_nonzero(row: &[f32], nz: &mut Vec<u32>) {
    nz.clear();
    for (q, &v) in row.iter().enumerate() {
        if v != 0.0 {
            nz.push(q as u32);
        }
    }
}

/// Sparse twin of [`vmm_dot`]: the same 4-aligned block grouping and
/// ascending-q order, visiting only the gathered nonzero coordinates.
/// Bit-identical to `vmm_dot` for finite weights (see the module-section
/// comment for the ±0.0 argument; verified exhaustively in tests).
#[inline]
fn vmm_dot_sparse(nz: &[u32], row: &[f32], wrow: &[f32], d: usize) -> f32 {
    let d4 = d & !3usize;
    let mut acc = 0.0f32;
    let mut i = 0usize;
    while i < nz.len() {
        let q0 = nz[i] as usize;
        if q0 >= d4 {
            break;
        }
        // everything in this aligned block of 4 sums left-to-right into
        // one partial, then joins the accumulator — vmm_dot's grouping
        let end = (q0 & !3usize) + 4;
        let mut bsum = row[q0] * wrow[q0];
        i += 1;
        while i < nz.len() && (nz[i] as usize) < end {
            let q = nz[i] as usize;
            bsum += row[q] * wrow[q];
            i += 1;
        }
        acc += bsum;
    }
    while i < nz.len() {
        let q = nz[i] as usize;
        acc += row[q] * wrow[q];
        i += 1;
    }
    acc
}

/// The portable scalar primitive set: `#[inline(always)]` delegation to
/// the bit-exact helpers above, so chunk kernels monomorphized over it
/// compile to exactly the code the scalar entry points have always run.
/// This is both the non-x86 implementation and the forced fallback the
/// `--kernels simd` mode routes to when the AVX2 probe fails.
pub struct ScalarPrims;

impl Prims for ScalarPrims {
    const ISA: Isa = Isa::Scalar;

    #[inline(always)]
    fn dot(row: &[f32], wrow: &[f32], d: usize) -> f32 {
        vmm_dot(row, wrow, d)
    }

    #[inline(always)]
    fn dot_sparse(nz: &[u32], row: &[f32], wrow: &[f32], d: usize) -> f32 {
        vmm_dot_sparse(nz, row, wrow, d)
    }

    #[inline(always)]
    fn axpy(orow: &mut [f32], g: f32, xrow: &[f32]) {
        axpy_dense(orow, g, xrow)
    }
}

/// Compound-sparsity masked VMM rows `[lo, hi)`: gather each row's
/// nonzero input coordinates once, then compute only the selected output
/// neurons from them — ops ~ nnz(in) * sel(out) instead of d * sel(out).
/// Bit-identical to [`vmm_rowmask_chunk`] on every branch.  Returns the
/// realized multiply-add count of the chunk (what the dispatch actually
/// executed), the measured quantity behind the Fig 9 reduction ratios.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_compound_chunk(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_rowmask_compound_chunk_p::<ScalarPrims>(xd, wd, d, n, mask, lo, hi, out)
}

/// [`vmm_rowmask_compound_chunk`] generic over the primitive set (the
/// per-row density dispatch picks `P::dot` vs `P::dot_sparse`).
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_compound_chunk_p<P: Prims>(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    let cutoff = compound_cutoff() * d as f32;
    let full = mask.is_full();
    if !full {
        out.fill(0.0);
    }
    let mut realized = 0u64;
    with_nz_scratch(|nz| {
        for i in lo..hi {
            let row = &xd[i * d..(i + 1) * d];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            let sel_len = if full { n } else { mask.row(i).len() };
            if sel_len == 0 {
                continue; // already zeroed
            }
            gather_nonzero(row, nz);
            // per-row dispatch: contiguous sweep for dense rows, indexed
            // accumulate for sparse ones — same bits either way
            let dense_row = nz.len() as f32 >= cutoff;
            if full {
                if dense_row {
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = P::dot(row, &wd[j * d..(j + 1) * d], d);
                    }
                } else {
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = P::dot_sparse(nz, row, &wd[j * d..(j + 1) * d], d);
                    }
                }
            } else if dense_row {
                for &j in mask.row(i) {
                    let j = j as usize;
                    orow[j] = P::dot(row, &wd[j * d..(j + 1) * d], d);
                }
            } else {
                for &j in mask.row(i) {
                    let j = j as usize;
                    orow[j] = P::dot_sparse(nz, row, &wd[j * d..(j + 1) * d], d);
                }
            }
            let per = if dense_row { d } else { nz.len() };
            realized += per as u64 * sel_len as u64;
        }
    });
    realized
}

/// Reusable CSR index of the nonzero coordinates of a row-major (m, d)
/// activation — the input-side twin of [`RowMask`].  The gradW backward
/// splits work by OUTPUT neuron, so every chunk walks every input row:
/// a prebuilt shared index keeps the gather at one O(m*d) pass per layer
/// instead of one per chunk.
#[derive(Clone, Debug, Default)]
pub struct NzIndex {
    rows: usize,
    width: usize,
    offsets: Vec<usize>,
    idx: Vec<u32>,
}

impl NzIndex {
    pub fn new() -> NzIndex {
        NzIndex { rows: 0, width: 0, offsets: vec![0], idx: Vec::new() }
    }

    /// Rebuild in place from a row-major (m, d) buffer (storage reused —
    /// allocation-free once warm, like `RowMask::fill_from_threshold`).
    pub fn fill_from_rows(&mut self, xd: &[f32], m: usize, d: usize) {
        debug_assert_eq!(xd.len(), m * d);
        assert!(d <= u32::MAX as usize, "row width {d} exceeds u32");
        self.rows = m;
        self.width = d;
        self.offsets.clear();
        self.offsets.reserve(m + 1);
        self.offsets.push(0);
        self.idx.clear();
        if d == 0 {
            // zero-width rows: every row is an empty list
            self.offsets.resize(m + 1, 0);
            return;
        }
        for row in xd.chunks_exact(d) {
            for (q, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    self.idx.push(q as u32);
                }
            }
            self.offsets.push(self.idx.len());
        }
    }

    /// Nonzero coordinates of row `i` (ascending).
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.idx[self.offsets[i]..self.offsets[i + 1]]
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Total nonzero count.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Measured nonzero fraction.
    pub fn density(&self) -> f64 {
        let total = self.rows * self.width;
        if total == 0 {
            return 0.0;
        }
        self.idx.len() as f64 / total as f64
    }
}

/// 4-wide-unrolled `orow[p] += g * xrow[p]` over all of `0..d`.  Each
/// slot is an independent accumulator, so the unroll cannot change bits.
#[inline]
fn axpy_dense(orow: &mut [f32], g: f32, xrow: &[f32]) {
    let d = orow.len();
    let mut p = 0;
    while p + 4 <= d {
        orow[p] += g * xrow[p];
        orow[p + 1] += g * xrow[p + 1];
        orow[p + 2] += g * xrow[p + 2];
        orow[p + 3] += g * xrow[p + 3];
        p += 4;
    }
    while p < d {
        orow[p] += g * xrow[p];
        p += 1;
    }
}

/// Indexed `orow[q] += g * xrow[q]` over the nonzero coordinates only.
/// Skipped coordinates would have added g * ±0.0 to a +0.0-started
/// accumulator — a bit-identity (see the module-section comment).
#[inline]
fn axpy_sparse(orow: &mut [f32], g: f32, xrow: &[f32], nz: &[u32]) {
    let mut t = 0;
    while t + 4 <= nz.len() {
        let (a, b, c, e) = (
            nz[t] as usize,
            nz[t + 1] as usize,
            nz[t + 2] as usize,
            nz[t + 3] as usize,
        );
        orow[a] += g * xrow[a];
        orow[b] += g * xrow[b];
        orow[c] += g * xrow[c];
        orow[e] += g * xrow[e];
        t += 4;
    }
    while t < nz.len() {
        let q = nz[t] as usize;
        orow[q] += g * xrow[q];
        t += 1;
    }
}

/// Compound backward-to-input of the RowMask VMM, rows `[lo, hi)`:
/// identical reads to [`vmm_rowmask_backward_chunk`] — only the SELECTED
/// and nonzero gradient entries are touched (for the backward op, dY IS
/// the sparse input side; dX must be written densely because the
/// upstream relu' owns the decision of which of its coordinates die).
/// The inner accumulate is 4-wide unrolled (independent slots =>
/// bit-identical).  Returns realized multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_backward_compound_chunk(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_rowmask_backward_compound_chunk_p::<ScalarPrims>(dyd, wd, d, n, mask, lo, hi, out)
}

/// [`vmm_rowmask_backward_compound_chunk`] generic over the primitive
/// set.
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_backward_compound_chunk_p<P: Prims>(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    mask: &RowMask,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    out.fill(0.0);
    let mut realized = 0u64;
    if mask.is_full() {
        for i in lo..hi {
            let dyrow = &dyd[i * n..(i + 1) * n];
            let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
            for (j, &g) in dyrow.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
                realized += d as u64;
            }
        }
        return realized;
    }
    for i in lo..hi {
        let dyrow = &dyd[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
        for &j in mask.row(i) {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
            realized += d as u64;
        }
    }
    realized
}

/// Compound backward-to-weights for OUTPUT NEURONS `[jlo, jhi)`: like
/// [`vmm_rowmask_gradw_chunk`] but reading only the LIVE input
/// coordinates of each x row through the prebuilt [`NzIndex`] — ops
/// ~ nnz(x_i) per live (i, j) pair instead of d.  Per-row density
/// dispatch falls back to the contiguous sweep for dense rows.
/// Bit-identical to the output-sparse kernel on every branch; returns
/// realized multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn vmm_rowmask_gradw_compound_chunk(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    nzx: &NzIndex,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_rowmask_gradw_compound_chunk_p::<ScalarPrims>(xd, dyd, m, d, n, mask, nzx, jlo, jhi, out)
}

/// [`vmm_rowmask_gradw_compound_chunk`] generic over the primitive set.
/// Only the dense-row axpy goes through `P`: the indexed `axpy_sparse`
/// scatter stays scalar on every ISA (AVX2 has no scatter, and an
/// emulated one loses to the scalar walk) — it is bit-exact regardless.
#[allow(clippy::too_many_arguments)]
fn vmm_rowmask_gradw_compound_chunk_p<P: Prims>(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    nzx: &NzIndex,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (jhi - jlo) * d);
    debug_assert_eq!(nzx.rows(), m, "nz index rows");
    out.fill(0.0);
    let cutoff = compound_cutoff() * d as f32;
    let full = mask.is_full();
    let mut realized = 0u64;
    for i in 0..m {
        let xrow = &xd[i * d..(i + 1) * d];
        let dyrow = &dyd[i * n..(i + 1) * n];
        let nz = nzx.row(i);
        if nz.is_empty() {
            continue; // all-zero input row contributes nothing
        }
        let dense_row = nz.len() as f32 >= cutoff;
        let per = if dense_row { d } else { nz.len() } as u64;
        let do_j = |j: usize, realized: &mut u64, out: &mut [f32]| {
            let g = dyrow[j];
            if g == 0.0 {
                return;
            }
            let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
            if dense_row {
                P::axpy(orow, g, xrow);
            } else {
                axpy_sparse(orow, g, xrow, nz);
            }
            *realized += per;
        };
        if full {
            for j in jlo..jhi {
                do_j(j, &mut realized, out);
            }
        } else {
            let sel = mask.row(i);
            let a = sel.partition_point(|&j| (j as usize) < jlo);
            let b = sel.partition_point(|&j| (j as usize) < jhi);
            for &j in &sel[a..b] {
                do_j(j as usize, &mut realized, out);
            }
        }
    }
    realized
}

/// Compound packed-gather forward for a FixedK mask, rows `[lo, hi)`:
/// the twin of [`vmm_rowmask_compound_chunk`]'s selected walk with a
/// fixed k-trip selection loop.  Same per-row density dispatch, same
/// bits on every branch; returns realized multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_compound_chunk(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_fixedk_compound_chunk_p::<ScalarPrims>(xd, wd, d, n, idx, k, lo, hi, out)
}

/// [`vmm_fixedk_compound_chunk`] generic over the primitive set.
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_compound_chunk_p<P: Prims>(
    xd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    if k == 0 {
        return 0; // nothing selected anywhere — chunk stays zero
    }
    let cutoff = compound_cutoff() * d as f32;
    let mut realized = 0u64;
    with_nz_scratch(|nz| {
        for i in lo..hi {
            let row = &xd[i * d..(i + 1) * d];
            let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            gather_nonzero(row, nz);
            let dense_row = nz.len() as f32 >= cutoff;
            let sel = &idx[i * k..(i + 1) * k];
            if dense_row {
                for &j in sel {
                    let j = j as usize;
                    orow[j] = P::dot(row, &wd[j * d..(j + 1) * d], d);
                }
            } else {
                for &j in sel {
                    let j = j as usize;
                    orow[j] = P::dot_sparse(nz, row, &wd[j * d..(j + 1) * d], d);
                }
            }
            let per = if dense_row { d } else { nz.len() };
            realized += per as u64 * k as u64;
        }
    });
    realized
}

/// Compound packed-gather backward-to-input for a FixedK mask, rows
/// `[lo, hi)`: the twin of [`vmm_rowmask_backward_compound_chunk`]'s
/// selected walk.  Returns realized multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_backward_compound_chunk(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_fixedk_backward_compound_chunk_p::<ScalarPrims>(dyd, wd, d, n, idx, k, lo, hi, out)
}

/// [`vmm_fixedk_backward_compound_chunk`] generic over the primitive
/// set.
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_backward_compound_chunk_p<P: Prims>(
    dyd: &[f32],
    wd: &[f32],
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    out.fill(0.0);
    let mut realized = 0u64;
    for i in lo..hi {
        let dyrow = &dyd[i * n..(i + 1) * n];
        let orow = &mut out[(i - lo) * d..(i - lo + 1) * d];
        for &j in &idx[i * k..(i + 1) * k] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            P::axpy(orow, g, &wd[j * d..(j + 1) * d]);
            realized += d as u64;
        }
    }
    realized
}

/// Compound packed-gather backward-to-weights for a FixedK mask, OUTPUT
/// NEURONS `[jlo, jhi)`: the twin of
/// [`vmm_rowmask_gradw_compound_chunk`]'s selected walk, reading live
/// input coordinates through the shared [`NzIndex`].  Returns realized
/// multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn vmm_fixedk_gradw_compound_chunk(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    nzx: &NzIndex,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) -> u64 {
    vmm_fixedk_gradw_compound_chunk_p::<ScalarPrims>(xd, dyd, m, d, n, idx, k, nzx, jlo, jhi, out)
}

/// [`vmm_fixedk_gradw_compound_chunk`] generic over the primitive set
/// (sparse-row scatter stays scalar, like the CSR twin).
#[allow(clippy::too_many_arguments)]
fn vmm_fixedk_gradw_compound_chunk_p<P: Prims>(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    idx: &[u32],
    k: usize,
    nzx: &NzIndex,
    jlo: usize,
    jhi: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(out.len(), (jhi - jlo) * d);
    debug_assert_eq!(nzx.rows(), m, "nz index rows");
    out.fill(0.0);
    let cutoff = compound_cutoff() * d as f32;
    let mut realized = 0u64;
    for i in 0..m {
        let xrow = &xd[i * d..(i + 1) * d];
        let dyrow = &dyd[i * n..(i + 1) * n];
        let nz = nzx.row(i);
        if nz.is_empty() {
            continue;
        }
        let dense_row = nz.len() as f32 >= cutoff;
        let per = if dense_row { d } else { nz.len() } as u64;
        let sel = &idx[i * k..(i + 1) * k];
        let a = sel.partition_point(|&j| (j as usize) < jlo);
        let b = sel.partition_point(|&j| (j as usize) < jhi);
        for &j in &sel[a..b] {
            let j = j as usize;
            let g = dyrow[j];
            if g == 0.0 {
                continue;
            }
            let orow = &mut out[(j - jlo) * d..(j - jlo + 1) * d];
            if dense_row {
                P::axpy(orow, g, xrow);
            } else {
                axpy_sparse(orow, g, xrow, nz);
            }
            realized += per;
        }
    }
    realized
}

/// Ternary projection of rows `[lo, hi)` into the chunk slice.
pub fn project_chunk(
    ridx: &crate::drs::projection::TernaryIndex,
    xd: &[f32],
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (hi - lo) * ridx.k);
    for i in lo..hi {
        ridx.project_row(
            &xd[i * ridx.d..(i + 1) * ridx.d],
            &mut out[(i - lo) * ridx.k..(i - lo + 1) * ridx.k],
        );
    }
}

// ---------------------------------------------------------------------------
// kernel dispatch table (ISA x mask layout x density band)
// ---------------------------------------------------------------------------

/// A full set of pre-instantiated monomorphized chunk kernels for one
/// ISA.  The table is the Dynasparse-style dispatch point: it is
/// resolved ONCE per process ([`active_kernels`]) from the runtime ISA
/// probe, and the `_kt` entry points then pick a row by (mask layout:
/// CSR vs packed FixedK) x (density band: plain vs compound) — so the
/// hot loops themselves contain no ISA branching at all.
///
/// The scalar table ([`scalar_kernels`]) points at the
/// [`ScalarPrims`] instantiations — literally the code the plain entry
/// points run — which is what makes the forced fallback
/// (`DSG_SIMD=off`, or a non-AVX2 host) bit-exact by construction.
pub struct KernelTable {
    /// Which primitive set this table runs on.
    pub isa: Isa,
    /// The ISA-matched ZVC bitmask/count pass (bit-identical across
    /// ISAs; the comparison is exact either way).
    pub zvc_bitmask: simd::BitmaskCountFn,
    fwd_csr: fn(&[f32], &[f32], usize, usize, &RowMask, usize, usize, &mut [f32]),
    fwd_packed: fn(&[f32], &[f32], usize, usize, &[u32], usize, usize, usize, &mut [f32]),
    bwd_csr: fn(&[f32], &[f32], usize, usize, &RowMask, usize, usize, &mut [f32]),
    bwd_packed: fn(&[f32], &[f32], usize, usize, &[u32], usize, usize, usize, &mut [f32]),
    gradw_csr: fn(&[f32], &[f32], usize, usize, usize, &RowMask, usize, usize, &mut [f32]),
    gradw_packed: fn(&[f32], &[f32], usize, usize, usize, &[u32], usize, usize, usize, &mut [f32]),
    fwd_csr_compound: fn(&[f32], &[f32], usize, usize, &RowMask, usize, usize, &mut [f32]) -> u64,
    fwd_packed_compound:
        fn(&[f32], &[f32], usize, usize, &[u32], usize, usize, usize, &mut [f32]) -> u64,
    bwd_csr_compound: fn(&[f32], &[f32], usize, usize, &RowMask, usize, usize, &mut [f32]) -> u64,
    bwd_packed_compound:
        fn(&[f32], &[f32], usize, usize, &[u32], usize, usize, usize, &mut [f32]) -> u64,
    gradw_csr_compound:
        fn(&[f32], &[f32], usize, usize, usize, &RowMask, &NzIndex, usize, usize, &mut [f32]) -> u64,
    gradw_packed_compound: fn(
        &[f32],
        &[f32],
        usize,
        usize,
        usize,
        &[u32],
        usize,
        &NzIndex,
        usize,
        usize,
        &mut [f32],
    ) -> u64,
}

static SCALAR_TABLE: KernelTable = KernelTable {
    isa: Isa::Scalar,
    zvc_bitmask: simd::bitmask_count_scalar,
    fwd_csr: vmm_rowmask_chunk_p::<ScalarPrims>,
    fwd_packed: vmm_fixedk_chunk_p::<ScalarPrims>,
    bwd_csr: vmm_rowmask_backward_chunk_p::<ScalarPrims>,
    bwd_packed: vmm_fixedk_backward_chunk_p::<ScalarPrims>,
    gradw_csr: vmm_rowmask_gradw_chunk_p::<ScalarPrims>,
    gradw_packed: vmm_fixedk_gradw_chunk_p::<ScalarPrims>,
    fwd_csr_compound: vmm_rowmask_compound_chunk_p::<ScalarPrims>,
    fwd_packed_compound: vmm_fixedk_compound_chunk_p::<ScalarPrims>,
    bwd_csr_compound: vmm_rowmask_backward_compound_chunk_p::<ScalarPrims>,
    bwd_packed_compound: vmm_fixedk_backward_compound_chunk_p::<ScalarPrims>,
    gradw_csr_compound: vmm_rowmask_gradw_compound_chunk_p::<ScalarPrims>,
    gradw_packed_compound: vmm_fixedk_gradw_compound_chunk_p::<ScalarPrims>,
};

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
static AVX2_TABLE: KernelTable = KernelTable {
    isa: Isa::Avx2Fma,
    zvc_bitmask: simd::bitmask_count_avx2,
    fwd_csr: vmm_rowmask_chunk_p::<simd::Avx2Prims>,
    fwd_packed: vmm_fixedk_chunk_p::<simd::Avx2Prims>,
    bwd_csr: vmm_rowmask_backward_chunk_p::<simd::Avx2Prims>,
    bwd_packed: vmm_fixedk_backward_chunk_p::<simd::Avx2Prims>,
    gradw_csr: vmm_rowmask_gradw_chunk_p::<simd::Avx2Prims>,
    gradw_packed: vmm_fixedk_gradw_chunk_p::<simd::Avx2Prims>,
    fwd_csr_compound: vmm_rowmask_compound_chunk_p::<simd::Avx2Prims>,
    fwd_packed_compound: vmm_fixedk_compound_chunk_p::<simd::Avx2Prims>,
    bwd_csr_compound: vmm_rowmask_backward_compound_chunk_p::<simd::Avx2Prims>,
    bwd_packed_compound: vmm_fixedk_backward_compound_chunk_p::<simd::Avx2Prims>,
    gradw_csr_compound: vmm_rowmask_gradw_compound_chunk_p::<simd::Avx2Prims>,
    gradw_packed_compound: vmm_fixedk_gradw_compound_chunk_p::<simd::Avx2Prims>,
};

/// The scalar (bit-exact contract) kernel table — what every non-`Simd`
/// kernel family runs on, and the `Simd` fallback when detection fails.
pub fn scalar_kernels() -> &'static KernelTable {
    &SCALAR_TABLE
}

/// The table the `--kernels simd` mode dispatches through: AVX2/FMA
/// when [`simd::active_isa`] probed positive (x86 only), otherwise the
/// scalar table.  Resolved once per process.
pub fn active_kernels() -> &'static KernelTable {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if simd::active_isa() == Isa::Avx2Fma {
            return &AVX2_TABLE;
        }
    }
    &SCALAR_TABLE
}

// ---------------------------------------------------------------------------
// allocation-free entry points
// ---------------------------------------------------------------------------

/// Pool-parallel GEMM x (m, k) * w (k, n) into `out` (len m*n).
pub fn matmul_parallel_into(
    xd: &[f32],
    m: usize,
    k: usize,
    wd: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * k);
    debug_assert_eq!(wd.len(), k * n);
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        matmul_chunk(xd, wd, k, n, lo, hi, chunk)
    });
}

/// Pool-parallel dense-mask VMM into `out` (len m*n).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_parallel_into(
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    md: &[f32],
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(wd.len(), n * d);
    debug_assert_eq!(md.len(), m * n);
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        vmm_mask_chunk(xd, wd, md, d, n, lo, hi, chunk)
    });
}

/// Pool-parallel RowMask VMM into `out` (len m*n).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_parallel_into(
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    dsg_vmm_rowmask_parallel_into_kt(&SCALAR_TABLE, xd, m, d, wd, n, mask, threads, out)
}

/// [`dsg_vmm_rowmask_parallel_into`] through an explicit
/// [`KernelTable`] — the `--kernels simd` route (callers pass
/// [`active_kernels`]).  With the scalar table this IS the plain entry
/// point: same chunk functions, same bits.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_parallel_into_kt(
    kt: &'static KernelTable,
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    // layout dispatch: a FixedK mask takes the packed-gather kernel
    // (fixed trip counts, no offsets loads) — bit-identical to the CSR
    // walk on the same selection
    if let Some((idx, k)) = mask.packed() {
        let f = kt.fwd_packed;
        for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
            f(xd, wd, d, n, idx, k, lo, hi, chunk)
        });
        return;
    }
    let f = kt.fwd_csr;
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        f(xd, wd, d, n, mask, lo, hi, chunk)
    });
}

/// Pool-parallel backward-to-input of the RowMask VMM into `out`
/// (len m*d): dX = (masked dY) @ W, reading only selected gradients.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_backward_parallel_into(
    dyd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    dsg_vmm_rowmask_backward_parallel_into_kt(&SCALAR_TABLE, dyd, m, d, wd, n, mask, threads, out)
}

/// [`dsg_vmm_rowmask_backward_parallel_into`] through an explicit
/// [`KernelTable`] (bit-exact on every table — the axpy accumulate has
/// independent slots).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_backward_parallel_into_kt(
    kt: &'static KernelTable,
    dyd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(dyd.len(), m * n);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    if let Some((idx, k)) = mask.packed() {
        let f = kt.bwd_packed;
        for_row_chunks(threads, m, d, out, |lo, hi, chunk| {
            f(dyd, wd, d, n, idx, k, lo, hi, chunk)
        });
        return;
    }
    let f = kt.bwd_csr;
    for_row_chunks(threads, m, d, out, |lo, hi, chunk| {
        f(dyd, wd, d, n, mask, lo, hi, chunk)
    });
}

/// Pool-parallel backward-to-weights of the RowMask VMM into the
/// transposed-layout gradient `out` (len n*d), split by output neuron.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_gradw_parallel_into(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    dsg_vmm_rowmask_gradw_parallel_into_kt(&SCALAR_TABLE, xd, dyd, m, d, n, mask, threads, out)
}

/// [`dsg_vmm_rowmask_gradw_parallel_into`] through an explicit
/// [`KernelTable`] (bit-exact on every table).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_gradw_parallel_into_kt(
    kt: &'static KernelTable,
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(dyd.len(), m * n);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    if let Some((idx, k)) = mask.packed() {
        let f = kt.gradw_packed;
        for_row_chunks(threads, n, d, out, |jlo, jhi, chunk| {
            f(xd, dyd, m, d, n, idx, k, jlo, jhi, chunk)
        });
        return;
    }
    let f = kt.gradw_csr;
    for_row_chunks(threads, n, d, out, |jlo, jhi, chunk| {
        f(xd, dyd, m, d, n, mask, jlo, jhi, chunk)
    });
}

/// Pool-parallel COMPOUND masked VMM into `out` (len m*n): input- and
/// output-side sparsity exploited together, bit-identical to
/// [`dsg_vmm_rowmask_parallel_into`] for any thread budget.
///
/// `in_density` is the per-layer dispatch hint — the caller's measured
/// nonzero fraction of `x` (previous layer's mask density adjusted for
/// ReLU/BN, or 1.0 for raw/unknown inputs).  At or above
/// [`compound_cutoff`] the layer routes to the output-sparse kernel and
/// never gathers; below it, rows are gathered and dispatched
/// individually.  Returns the realized multiply-add count.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_compound_parallel_into(
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    in_density: f32,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    dsg_vmm_compound_parallel_into_kt(&SCALAR_TABLE, xd, m, d, wd, n, mask, in_density, threads, out)
}

/// [`dsg_vmm_compound_parallel_into`] through an explicit
/// [`KernelTable`] — the per-layer density band (plain vs compound) and
/// the mask layout (CSR vs packed) pick the table row; the table itself
/// was picked once per process from the ISA probe.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_compound_parallel_into_kt(
    kt: &'static KernelTable,
    xd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    in_density: f32,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    if in_density >= compound_cutoff() {
        // dense-enough input: output-sparse only, packed when FixedK
        dsg_vmm_rowmask_parallel_into_kt(kt, xd, m, d, wd, n, mask, threads, out);
        return d as u64 * mask.selected() as u64;
    }
    let realized = AtomicU64::new(0);
    if let Some((idx, k)) = mask.packed() {
        let f = kt.fwd_packed_compound;
        for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
            let r = f(xd, wd, d, n, idx, k, lo, hi, chunk);
            realized.fetch_add(r, Ordering::Relaxed);
        });
        return realized.into_inner();
    }
    let f = kt.fwd_csr_compound;
    for_row_chunks(threads, m, n, out, |lo, hi, chunk| {
        let r = f(xd, wd, d, n, mask, lo, hi, chunk);
        realized.fetch_add(r, Ordering::Relaxed);
    });
    realized.into_inner()
}

/// Pool-parallel compound backward-to-input into `out` (len m*d):
/// bit-identical to [`dsg_vmm_rowmask_backward_parallel_into`]; returns
/// realized multiply-adds (only selected, nonzero gradient entries are
/// read, so the count is the measured backward sparsity).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_backward_compound_parallel_into(
    dyd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    dsg_vmm_rowmask_backward_compound_parallel_into_kt(
        &SCALAR_TABLE,
        dyd,
        m,
        d,
        wd,
        n,
        mask,
        threads,
        out,
    )
}

/// [`dsg_vmm_rowmask_backward_compound_parallel_into`] through an
/// explicit [`KernelTable`] (bit-exact on every table).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_backward_compound_parallel_into_kt(
    kt: &'static KernelTable,
    dyd: &[f32],
    m: usize,
    d: usize,
    wd: &[f32],
    n: usize,
    mask: &RowMask,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(dyd.len(), m * n);
    debug_assert_eq!(wd.len(), n * d);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    let realized = AtomicU64::new(0);
    if let Some((idx, k)) = mask.packed() {
        let f = kt.bwd_packed_compound;
        for_row_chunks(threads, m, d, out, |lo, hi, chunk| {
            let r = f(dyd, wd, d, n, idx, k, lo, hi, chunk);
            realized.fetch_add(r, Ordering::Relaxed);
        });
        return realized.into_inner();
    }
    let f = kt.bwd_csr_compound;
    for_row_chunks(threads, m, d, out, |lo, hi, chunk| {
        let r = f(dyd, wd, d, n, mask, lo, hi, chunk);
        realized.fetch_add(r, Ordering::Relaxed);
    });
    realized.into_inner()
}

/// Pool-parallel compound backward-to-weights into the transposed-layout
/// gradient `out` (len n*d), split by output neuron, reading only live
/// input coordinates via the caller's prebuilt [`NzIndex`].
/// Bit-identical to [`dsg_vmm_rowmask_gradw_parallel_into`]; returns
/// realized multiply-adds.
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_gradw_compound_parallel_into(
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    nzx: &NzIndex,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    dsg_vmm_rowmask_gradw_compound_parallel_into_kt(
        &SCALAR_TABLE,
        xd,
        dyd,
        m,
        d,
        n,
        mask,
        nzx,
        threads,
        out,
    )
}

/// [`dsg_vmm_rowmask_gradw_compound_parallel_into`] through an explicit
/// [`KernelTable`] (bit-exact on every table).
#[allow(clippy::too_many_arguments)]
pub fn dsg_vmm_rowmask_gradw_compound_parallel_into_kt(
    kt: &'static KernelTable,
    xd: &[f32],
    dyd: &[f32],
    m: usize,
    d: usize,
    n: usize,
    mask: &RowMask,
    nzx: &NzIndex,
    threads: usize,
    out: &mut [f32],
) -> u64 {
    debug_assert_eq!(xd.len(), m * d);
    debug_assert_eq!(dyd.len(), m * n);
    assert_eq!(mask.rows(), m, "mask rows");
    assert_eq!(mask.width(), n, "mask width");
    let realized = AtomicU64::new(0);
    if let Some((idx, k)) = mask.packed() {
        let f = kt.gradw_packed_compound;
        for_row_chunks(threads, n, d, out, |jlo, jhi, chunk| {
            let r = f(xd, dyd, m, d, n, idx, k, nzx, jlo, jhi, chunk);
            realized.fetch_add(r, Ordering::Relaxed);
        });
        return realized.into_inner();
    }
    let f = kt.gradw_csr_compound;
    for_row_chunks(threads, n, d, out, |jlo, jhi, chunk| {
        let r = f(xd, dyd, m, d, n, mask, nzx, jlo, jhi, chunk);
        realized.fetch_add(r, Ordering::Relaxed);
    });
    realized.into_inner()
}

/// Pool-parallel ternary projection into `out` (len m*k).
pub fn project_rows_parallel_into(
    xd: &[f32],
    m: usize,
    ridx: &crate::drs::projection::TernaryIndex,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), m * ridx.d);
    for_row_chunks(threads, m, ridx.k, out, |lo, hi, chunk| {
        project_chunk(ridx, xd, lo, hi, chunk)
    });
}

// ---------------------------------------------------------------------------
// Tensor wrappers
// ---------------------------------------------------------------------------

/// Parallel blocked GEMM: x (m, k) * w (k, n).
pub fn matmul_parallel(x: &Tensor, w: &Tensor) -> Tensor {
    matmul_parallel_with(x, w, n_threads())
}

/// `matmul_parallel` with an explicit thread budget.  Results are
/// bit-exact for ANY budget: work splits by output rows and each output
/// element's accumulation order never changes — the serving layer relies
/// on this to keep predictions identical across worker counts.
pub fn matmul_parallel_with(x: &Tensor, w: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    matmul_parallel_into(x.data(), m, k, w.data(), n, threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel DSG masked VMM over transposed weights wt (n, d), dense f32
/// mask (m, n).
pub fn dsg_vmm_parallel(x: &Tensor, wt: &Tensor, mask: &Tensor) -> Tensor {
    dsg_vmm_parallel_with(x, wt, mask, n_threads())
}

/// `dsg_vmm_parallel` with an explicit thread budget (bit-exact for any
/// budget — row split only, per-row op order unchanged).
pub fn dsg_vmm_parallel_with(x: &Tensor, wt: &Tensor, mask: &Tensor, threads: usize) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.shape(), &[m, n]);
    let mut out = vec![0.0f32; m * n];
    dsg_vmm_parallel_into(x.data(), m, d, wt.data(), n, mask.data(), threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel DSG masked VMM over a compact [`RowMask`].
pub fn dsg_vmm_rowmask_parallel(x: &Tensor, wt: &Tensor, mask: &RowMask) -> Tensor {
    dsg_vmm_rowmask_parallel_with(x, wt, mask, n_threads())
}

/// `dsg_vmm_rowmask_parallel` with an explicit thread budget.  Bit-exact
/// with the dense-mask engine for the same selection, and across
/// budgets.
pub fn dsg_vmm_rowmask_parallel_with(
    x: &Tensor,
    wt: &Tensor,
    mask: &RowMask,
    threads: usize,
) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    let mut out = vec![0.0f32; m * n];
    dsg_vmm_rowmask_parallel_into(x.data(), m, d, wt.data(), n, mask, threads, &mut out);
    Tensor::new(&[m, n], out)
}

/// Parallel COMPOUND masked VMM (Tensor wrapper): returns the product
/// and the realized multiply-add count.  Bit-exact with the
/// output-sparse and dense-mask engines for the same selection, for any
/// `in_density` hint and any thread budget.
pub fn dsg_vmm_compound_parallel_with(
    x: &Tensor,
    wt: &Tensor,
    mask: &RowMask,
    in_density: f32,
    threads: usize,
) -> (Tensor, u64) {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    let mut out = vec![0.0f32; m * n];
    let realized =
        dsg_vmm_compound_parallel_into(x.data(), m, d, wt.data(), n, mask, in_density, threads, &mut out);
    (Tensor::new(&[m, n], out), realized)
}

/// Parallel row projection through a ternary index.
pub fn project_rows_parallel(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
) -> Tensor {
    project_rows_parallel_with(x, ridx, n_threads())
}

/// `project_rows_parallel` with an explicit thread budget (bit-exact
/// for any budget).
pub fn project_rows_parallel_with(
    x: &Tensor,
    ridx: &crate::drs::projection::TernaryIndex,
    threads: usize,
) -> Tensor {
    let m = x.shape()[0];
    let mut out = vec![0.0f32; m * ridx.k];
    project_rows_parallel_into(x.data(), m, ridx, threads, &mut out);
    Tensor::new(&[m, ridx.k], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drs::projection::{ternary_r, TernaryIndex};
    use crate::tensor::ops;
    use crate::util::Pcg32;

    fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn row_chunks_cover_exactly() {
        for rows in [1usize, 5, 16, 100, 101] {
            for parts in [1usize, 2, 7, 16] {
                let ch = row_chunks(rows, parts);
                assert_eq!(ch[0].0, 0);
                assert_eq!(ch.last().unwrap().1, rows);
                for w in ch.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = Pcg32::seeded(61);
        let x = randn(&mut rng, &[37, 120]);
        let w = randn(&mut rng, &[120, 53]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_blocked(&x, &w);
        assert!(a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn parallel_dsg_vmm_matches_serial() {
        let mut rng = Pcg32::seeded(62);
        let x = randn(&mut rng, &[29, 64]);
        let w = randn(&mut rng, &[64, 31]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[29, 31], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        let a = dsg_vmm_parallel(&x, &wt, &mask);
        let b = crate::sparse::dsg_vmm(&x, &wt, &mask);
        assert_eq!(a, b); // identical op order per row => bit-exact
    }

    #[test]
    fn rowmask_vmm_matches_dense_mask_vmm() {
        let mut rng = Pcg32::seeded(66);
        let x = randn(&mut rng, &[29, 64]);
        let w = randn(&mut rng, &[64, 31]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[29, 31], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        for t in [1usize, 3] {
            let dense = dsg_vmm_parallel_with(&x, &wt, &mask, t);
            let compact = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t);
            assert_eq!(dense, compact, "threads {t}");
        }
    }

    #[test]
    fn parallel_projection_matches_serial() {
        let mut rng = Pcg32::seeded(63);
        let x = randn(&mut rng, &[19, 96]);
        let r = ternary_r(&mut rng, 24, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let a = project_rows_parallel(&x, &ridx);
        let b = crate::drs::project_rows(&x, &r);
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_thread_budgets_are_bit_exact() {
        // The serving layer divides cores across workers, so the SAME
        // inputs must give the SAME bits under any thread budget.
        let mut rng = Pcg32::seeded(65);
        let x = randn(&mut rng, &[23, 96]);
        let w = randn(&mut rng, &[96, 41]);
        let wt = ops::transpose(&w);
        let mask = Tensor::from_fn(&[23, 41], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        let r = ternary_r(&mut rng, 16, 96, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let mm1 = matmul_parallel_with(&x, &w, 1);
        let vm1 = dsg_vmm_parallel_with(&x, &wt, &mask, 1);
        let rm1 = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, 1);
        let pr1 = project_rows_parallel_with(&x, &ridx, 1);
        for t in [2usize, 3, 8] {
            assert_eq!(mm1, matmul_parallel_with(&x, &w, t), "matmul @ {t}");
            assert_eq!(vm1, dsg_vmm_parallel_with(&x, &wt, &mask, t), "vmm @ {t}");
            assert_eq!(rm1, dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t), "rowmask @ {t}");
            assert_eq!(pr1, project_rows_parallel_with(&x, &ridx, t), "proj @ {t}");
        }
    }

    /// Reference backward-to-input: dX = (dY * dense mask) @ W.
    fn backward_input_reference(dy: &Tensor, w: &Tensor, mask: &Tensor) -> Tensor {
        let masked = Tensor::from_fn(dy.shape(), |i| dy.data()[i] * mask.data()[i]);
        ops::matmul_naive(&masked, w)
    }

    /// Reference backward-to-weights: dW^T = (dY * mask)^T @ X, (n, d).
    fn gradw_reference(x: &Tensor, dy: &Tensor, mask: &Tensor) -> Tensor {
        let masked = Tensor::from_fn(dy.shape(), |i| dy.data()[i] * mask.data()[i]);
        ops::matmul_naive(&ops::transpose(&masked), x)
    }

    #[test]
    fn rowmask_backward_matches_dense_reference() {
        let mut rng = Pcg32::seeded(71);
        let (m, d, n) = (13, 40, 21);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let dy = randn(&mut rng, &[m, n]);
        for frac in [0usize, 3, 1] {
            // frac 0 = empty-ish, 3 = quarter, 1 = full mask
            let mask = Tensor::from_fn(&[m, n], |i| if frac == 0 { 0.0 } else if i % frac == 0 { 1.0 } else { 0.0 });
            let rm = RowMask::from_dense(&mask);
            let want_dx = backward_input_reference(&dy, &w, &mask);
            let want_dwt = gradw_reference(&x, &dy, &mask);
            let mut dx = vec![f32::NAN; m * d];
            let mut dwt = vec![f32::NAN; n * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, 1, &mut dx);
            dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, 1, &mut dwt);
            let dx_t = Tensor::new(&[m, d], dx);
            let dwt_t = Tensor::new(&[n, d], dwt);
            assert!(dx_t.allclose(&want_dx, 1e-4, 1e-4), "dx frac {frac}");
            assert!(dwt_t.allclose(&want_dwt, 1e-4, 1e-4), "dwt frac {frac}");
        }
    }

    #[test]
    fn backward_kernels_bit_exact_across_budgets() {
        let mut rng = Pcg32::seeded(72);
        let (m, d, n) = (17, 48, 33);
        let x = randn(&mut rng, &[m, d]);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let dy = randn(&mut rng, &[m, n]);
        let mask = Tensor::from_fn(&[m, n], |i| if i % 3 == 0 { 1.0 } else { 0.0 });
        let rm = RowMask::from_dense(&mask);
        let mut dx1 = vec![0.0f32; m * d];
        let mut dwt1 = vec![0.0f32; n * d];
        dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, 1, &mut dx1);
        dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, 1, &mut dwt1);
        for t in [2usize, 3, 8] {
            let mut dx = vec![0.0f32; m * d];
            let mut dwt = vec![0.0f32; n * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, t, &mut dx);
            dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, t, &mut dwt);
            assert_eq!(dx1, dx, "backward @ {t}");
            assert_eq!(dwt1, dwt, "gradw @ {t}");
        }
    }

    #[test]
    fn sparse_dot_bit_identical_to_dense_dot() {
        // the compound kernel's core claim, hammered across shapes and
        // signed-zero placements: vmm_dot_sparse over the nonzero
        // coordinates == vmm_dot over everything, to the BIT
        let mut rng = Pcg32::seeded(81);
        for trial in 0..200 {
            let d = 1 + (trial % 37);
            let mut row: Vec<f32> = rng.normal_vec(d, 1.0);
            let mut wrow: Vec<f32> = rng.normal_vec(d, 1.0);
            for q in 0..d {
                match trial.wrapping_add(q) % 5 {
                    0 => row[q] = 0.0,
                    1 => row[q] = -0.0,
                    2 => wrow[q] = 0.0,
                    3 => wrow[q] = -0.0,
                    _ => {}
                }
            }
            let nz: Vec<u32> = (0..d).filter(|&q| row[q] != 0.0).map(|q| q as u32).collect();
            let a = vmm_dot(&row, &wrow, d);
            let b = vmm_dot_sparse(&nz, &row, &wrow, d);
            assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} d {d}: {a} vs {b}");
        }
    }

    /// Input with mask-style + relu-style zeros (and a few signed
    /// zeros): the shape the compound kernels are built for.
    fn sparse_input(rng: &mut Pcg32, m: usize, d: usize) -> Tensor {
        let mut v = rng.normal_vec(m * d, 1.0);
        for (i, x) in v.iter_mut().enumerate() {
            if i % 3 == 0 {
                *x = 0.0;
            } else if i % 7 == 0 {
                *x = -0.0;
            } else if *x < -0.5 {
                *x = 0.0; // relu-ish
            }
        }
        Tensor::new(&[m, d], v)
    }

    #[test]
    fn compound_vmm_bit_identical_to_output_sparse() {
        let mut rng = Pcg32::seeded(82);
        let (m, d, n) = (19, 53, 27); // d not a multiple of 4: tail path
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        for frac in [0usize, 4, 1] {
            // 0 = empty mask, 4 = quarter, 1 = keep-all
            let mask = Tensor::from_fn(&[m, n], |i| {
                if frac == 0 {
                    0.0
                } else if i % frac == 0 {
                    1.0
                } else {
                    0.0
                }
            });
            let rm = RowMask::from_dense(&mask);
            let want = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, 1);
            // every layer hint and every budget: same bits, and the
            // realized count never exceeds the output-sparse cost
            for hint in [0.0f32, 0.3, 0.5, 1.0] {
                for t in [1usize, 2, 3, 8] {
                    let (got, realized) = dsg_vmm_compound_parallel_with(&x, &wt, &rm, hint, t);
                    assert_eq!(want, got, "frac {frac} hint {hint} threads {t}");
                    assert!(
                        realized <= d as u64 * rm.selected() as u64,
                        "frac {frac} hint {hint}: realized {realized} > output-sparse"
                    );
                }
            }
        }
    }

    #[test]
    fn compound_vmm_all_zero_rows_and_empty_mask_rows() {
        let mut rng = Pcg32::seeded(83);
        let (m, d, n) = (6, 32, 9);
        let mut xv = rng.normal_vec(m * d, 1.0);
        xv[2 * d..3 * d].fill(0.0); // row 2 entirely zero
        let x = Tensor::new(&[m, d], xv);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        // rows 1 and 4 select nothing
        let mask = Tensor::from_fn(&[m, n], |i| {
            let r = i / n;
            if r == 1 || r == 4 {
                0.0
            } else if i % 2 == 0 {
                1.0
            } else {
                0.0
            }
        });
        let rm = RowMask::from_dense(&mask);
        let want = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, 1);
        for t in [1usize, 3] {
            let (got, _) = dsg_vmm_compound_parallel_with(&x, &wt, &rm, 0.0, t);
            assert_eq!(want, got, "threads {t}");
        }
    }

    #[test]
    fn compound_backward_kernels_bit_identical_and_budget_invariant() {
        let mut rng = Pcg32::seeded(84);
        let (m, d, n) = (13, 41, 22);
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let mut dyv = rng.normal_vec(m * n, 1.0);
        for (i, g) in dyv.iter_mut().enumerate() {
            if i % 5 == 0 {
                *g = 0.0; // relu'd-away gradients
            }
        }
        let dy = Tensor::new(&[m, n], dyv);
        let mut nzx = NzIndex::new();
        nzx.fill_from_rows(x.data(), m, d);
        for frac in [0usize, 3, 1] {
            let mask = Tensor::from_fn(&[m, n], |i| {
                if frac == 0 {
                    0.0
                } else if i % frac == 0 {
                    1.0
                } else {
                    0.0
                }
            });
            let rm = RowMask::from_dense(&mask);
            let mut dx_ref = vec![0.0f32; m * d];
            let mut dwt_ref = vec![0.0f32; n * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, 1, &mut dx_ref);
            dsg_vmm_rowmask_gradw_parallel_into(x.data(), dy.data(), m, d, n, &rm, 1, &mut dwt_ref);
            for t in [1usize, 2, 3, 8] {
                let mut dx = vec![f32::NAN; m * d];
                let mut dwt = vec![f32::NAN; n * d];
                let r1 = dsg_vmm_rowmask_backward_compound_parallel_into(
                    dy.data(), m, d, wt.data(), n, &rm, t, &mut dx,
                );
                let r2 = dsg_vmm_rowmask_gradw_compound_parallel_into(
                    x.data(), dy.data(), m, d, n, &rm, &nzx, t, &mut dwt,
                );
                assert_eq!(
                    dx.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dx_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "dx frac {frac} threads {t}"
                );
                assert_eq!(
                    dwt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dwt_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "dwt frac {frac} threads {t}"
                );
                assert!(r1 <= m as u64 * n as u64 * d as u64);
                assert!(r2 <= d as u64 * rm.selected() as u64, "frac {frac}: gradw realized");
            }
        }
    }

    #[test]
    fn nz_index_matches_scan() {
        let mut rng = Pcg32::seeded(85);
        let x = sparse_input(&mut rng, 7, 29);
        let mut nzx = NzIndex::new();
        nzx.fill_from_rows(x.data(), 7, 29);
        assert_eq!(nzx.rows(), 7);
        let mut total = 0usize;
        for i in 0..7 {
            let want: Vec<u32> = (0..29)
                .filter(|&q| x.data()[i * 29 + q] != 0.0)
                .map(|q| q as u32)
                .collect();
            assert_eq!(nzx.row(i), &want[..], "row {i}");
            total += want.len();
        }
        assert_eq!(nzx.nnz(), total);
        assert!((nzx.density() - total as f64 / (7.0 * 29.0)).abs() < 1e-12);
        // refill with a different shape reuses storage and stays correct
        nzx.fill_from_rows(&x.data()[..3 * 29], 3, 29);
        assert_eq!(nzx.rows(), 3);
    }

    #[test]
    fn compound_realized_ops_track_input_sparsity() {
        // at ~1/3 input density and a sparse hint, the compound kernel
        // must realize FEWER multiply-adds than the output-sparse cost
        let mut rng = Pcg32::seeded(86);
        let (m, d, n) = (16, 96, 48);
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let virt = randn(&mut rng, &[m, n]);
        let rm = crate::drs::topk::select_rowmask(&virt, 0.5);
        let out_sparse_ops = d as u64 * rm.selected() as u64;
        let (_, realized) = dsg_vmm_compound_parallel_with(&x, &wt, &rm, 0.3, 1);
        assert!(
            realized * 2 < out_sparse_ops,
            "realized {realized} not well under output-sparse {out_sparse_ops}"
        );
        // a dense hint routes to the output-sparse kernel: exact cost
        let (_, dense_hint) = dsg_vmm_compound_parallel_with(&x, &wt, &rm, 1.0, 1);
        assert_eq!(dense_hint, out_sparse_ops);
    }

    #[test]
    fn packed_gather_kernels_bit_identical_to_csr_twins() {
        // the SAME structured selection, expressed packed (FixedK) and
        // as explicit CSR: every kernel family — forward, backward-dX,
        // gradW, and their compound twins — must agree bit-for-bit at
        // every thread budget, and the compound realized counts must
        // match (layout moves loads, never bits or accounting)
        let mut rng = Pcg32::seeded(87);
        let (m, d, n) = (17, 45, 23); // d, n not multiples of 4: tail paths
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let mut dyv = rng.normal_vec(m * n, 1.0);
        for (i, g) in dyv.iter_mut().enumerate() {
            if i % 4 == 0 {
                *g = 0.0;
            }
        }
        let dy = Tensor::new(&[m, n], dyv);
        let mut nzx = NzIndex::new();
        nzx.fill_from_rows(x.data(), m, d);
        let virt = randn(&mut rng, &[m, n]);
        for blocked in [false, true] {
            let packed = crate::drs::topk::select_structured(&virt, 0.6, blocked);
            assert!(packed.fixed_k().is_some(), "blocked {blocked}");
            let csr = packed.to_csr();
            assert_eq!(packed.selected(), csr.selected());
            let y_ref = dsg_vmm_rowmask_parallel_with(&x, &wt, &csr, 1);
            let mut dx_ref = vec![0.0f32; m * d];
            let mut dwt_ref = vec![0.0f32; n * d];
            dsg_vmm_rowmask_backward_parallel_into(
                dy.data(), m, d, wt.data(), n, &csr, 1, &mut dx_ref,
            );
            dsg_vmm_rowmask_gradw_parallel_into(
                x.data(), dy.data(), m, d, n, &csr, 1, &mut dwt_ref,
            );
            for t in [1usize, 2, 3, 8] {
                assert_eq!(
                    y_ref,
                    dsg_vmm_rowmask_parallel_with(&x, &wt, &packed, t),
                    "forward blocked {blocked} threads {t}"
                );
                let mut dx = vec![f32::NAN; m * d];
                let mut dwt = vec![f32::NAN; n * d];
                dsg_vmm_rowmask_backward_parallel_into(
                    dy.data(), m, d, wt.data(), n, &packed, t, &mut dx,
                );
                dsg_vmm_rowmask_gradw_parallel_into(
                    x.data(), dy.data(), m, d, n, &packed, t, &mut dwt,
                );
                assert_eq!(
                    dx.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dx_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "dx blocked {blocked} threads {t}"
                );
                assert_eq!(
                    dwt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dwt_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "dwt blocked {blocked} threads {t}"
                );
                for hint in [0.0f32, 0.3, 1.0] {
                    let (yc, rc) = dsg_vmm_compound_parallel_with(&x, &wt, &csr, hint, t);
                    let (yp, rp) = dsg_vmm_compound_parallel_with(&x, &wt, &packed, hint, t);
                    assert_eq!(y_ref, yc, "compound csr hint {hint} threads {t}");
                    assert_eq!(y_ref, yp, "compound packed hint {hint} threads {t}");
                    assert_eq!(rc, rp, "realized hint {hint} threads {t}");
                }
                let mut dxc = vec![f32::NAN; m * d];
                let r1c = dsg_vmm_rowmask_backward_compound_parallel_into(
                    dy.data(), m, d, wt.data(), n, &csr, t, &mut dxc,
                );
                let mut dxp = vec![f32::NAN; m * d];
                let r1p = dsg_vmm_rowmask_backward_compound_parallel_into(
                    dy.data(), m, d, wt.data(), n, &packed, t, &mut dxp,
                );
                assert_eq!(r1c, r1p, "compound dx realized, threads {t}");
                assert_eq!(
                    dxp.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dx_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "compound dx blocked {blocked} threads {t}"
                );
                assert_eq!(
                    dxc.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dx_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                );
                let mut dwc = vec![f32::NAN; n * d];
                let r2c = dsg_vmm_rowmask_gradw_compound_parallel_into(
                    x.data(), dy.data(), m, d, n, &csr, &nzx, t, &mut dwc,
                );
                let mut dwp = vec![f32::NAN; n * d];
                let r2p = dsg_vmm_rowmask_gradw_compound_parallel_into(
                    x.data(), dy.data(), m, d, n, &packed, &nzx, t, &mut dwp,
                );
                assert_eq!(r2c, r2p, "compound dwt realized, threads {t}");
                assert_eq!(
                    dwp.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dwt_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "compound dwt blocked {blocked} threads {t}"
                );
                assert_eq!(
                    dwc.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    dwt_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                );
            }
        }
    }

    #[test]
    fn packed_kernels_handle_k_zero() {
        // a FixedK mask with k = 0 (every row empty) must produce all
        // zeros through every packed entry, at any budget
        let mut rng = Pcg32::seeded(88);
        let (m, d, n) = (5, 24, 7);
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[d, n]);
        let wt = ops::transpose(&w);
        let dy = randn(&mut rng, &[m, n]);
        let mut rm = RowMask::new();
        rm.fill_topk(&vec![0.0f32; m * n], m, n, 0, &mut Vec::new());
        assert_eq!(rm.fixed_k(), Some(0));
        let mut nzx = NzIndex::new();
        nzx.fill_from_rows(x.data(), m, d);
        for t in [1usize, 3] {
            let y = dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t);
            assert!(y.data().iter().all(|&v| v == 0.0), "forward threads {t}");
            let (yc, r) = dsg_vmm_compound_parallel_with(&x, &wt, &rm, 0.0, t);
            assert_eq!(y, yc);
            assert_eq!(r, 0);
            let mut dx = vec![f32::NAN; m * d];
            dsg_vmm_rowmask_backward_parallel_into(dy.data(), m, d, wt.data(), n, &rm, t, &mut dx);
            assert!(dx.iter().all(|&v| v == 0.0), "dx threads {t}");
            let mut dwt = vec![f32::NAN; n * d];
            let r2 = dsg_vmm_rowmask_gradw_compound_parallel_into(
                x.data(), dy.data(), m, d, n, &rm, &nzx, t, &mut dwt,
            );
            assert!(dwt.iter().all(|&v| v == 0.0), "dwt threads {t}");
            assert_eq!(r2, 0);
        }
    }

    #[test]
    fn single_row_works() {
        let mut rng = Pcg32::seeded(64);
        let x = randn(&mut rng, &[1, 16]);
        let w = randn(&mut rng, &[16, 8]);
        let a = matmul_parallel(&x, &w);
        let b = ops::matmul_naive(&x, &w);
        assert!(a.allclose(&b, 1e-4, 1e-4));
    }

    #[test]
    fn into_variants_reuse_buffers() {
        // steady-state: the same output buffer survives repeated calls
        let mut rng = Pcg32::seeded(67);
        let x = randn(&mut rng, &[9, 32]);
        let w = randn(&mut rng, &[32, 11]);
        let want = matmul_parallel_with(&x, &w, 2);
        let mut out = vec![f32::NAN; 9 * 11];
        for _ in 0..3 {
            matmul_parallel_into(x.data(), 9, 32, w.data(), 11, 2, &mut out);
            assert_eq!(out, want.data());
        }
    }

    #[test]
    fn cutoff_env_rejects_non_finite() {
        // regression: DSG_COMPOUND_CUTOFF=NaN used to survive f32::clamp
        // (clamp passes NaN through), making every `density >= cutoff`
        // comparison false and silently forcing the gather path everywhere
        assert_eq!(cutoff_from_env(None), (0.5, None));
        assert_eq!(cutoff_from_env(Some("0.3")), (0.3, None));
        // finite out-of-range values still clamp silently
        assert_eq!(cutoff_from_env(Some("1.5")).0, 1.0);
        assert_eq!(cutoff_from_env(Some("-2")).0, 0.0);
        for bad in ["NaN", "nan", "-NaN", "inf", "-inf", "infinity"] {
            let (c, warning) = cutoff_from_env(Some(bad));
            assert_eq!(c, 0.5, "{bad} must fall back to the default");
            let w = warning.expect("non-finite cutoff must warn");
            assert!(w.contains("DSG_COMPOUND_CUTOFF"), "warning names the variable: {w}");
            assert!(w.contains("0.5"), "warning names the fallback: {w}");
        }
        let (c, warning) = cutoff_from_env(Some("dense"));
        assert_eq!(c, 0.5);
        assert!(warning.unwrap().contains("DSG_COMPOUND_CUTOFF"));
    }

    #[test]
    fn threads_env_warns_on_invalid() {
        assert_eq!(threads_from_env(None, 8), (8, None));
        assert_eq!(threads_from_env(Some("4"), 8), (4, None));
        let (n, warning) = threads_from_env(Some("0"), 8);
        assert_eq!(n, 1);
        assert!(warning.unwrap().contains("DSG_THREADS"));
        for bad in ["abc", "-1", "1.5", ""] {
            let (n, warning) = threads_from_env(Some(bad), 8);
            assert_eq!(n, 8, "{bad:?} must fall back to the core count");
            let w = warning.expect("invalid DSG_THREADS must warn");
            assert!(w.contains("DSG_THREADS"), "warning names the variable: {w}");
            assert!(w.contains('8'), "warning names the fallback: {w}");
        }
    }

    #[test]
    fn scalar_table_is_the_plain_entry_point() {
        // the forced-fallback guarantee: routing through the scalar
        // KernelTable is bit-identical to the plain entry points (same
        // chunk functions, reached through fn pointers)
        let mut rng = Pcg32::seeded(91);
        let (m, d, n) = (13, 37, 21);
        let x = sparse_input(&mut rng, m, d);
        let w = randn(&mut rng, &[n, d]);
        let virt = randn(&mut rng, &[m, n]);
        let mask = crate::drs::topk::select_rowmask(&virt, 0.5);
        let kt = scalar_kernels();
        assert_eq!(kt.isa, Isa::Scalar);
        for hint in [0.1f32, 0.9] {
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            let ra = dsg_vmm_compound_parallel_into(x.data(), m, d, w.data(), n, &mask, hint, 3, &mut a);
            let rb = dsg_vmm_compound_parallel_into_kt(
                kt,
                x.data(),
                m,
                d,
                w.data(),
                n,
                &mask,
                hint,
                3,
                &mut b,
            );
            assert_eq!(ra, rb, "realized ops at hint {hint}");
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "forward bits at hint {hint}"
            );
        }
    }
}
