//! Persistent worker pool for the sparse engines.
//!
//! The scoped-thread engines of the first perf pass spawned (and joined)
//! fresh OS threads for every layer of every request — tens of
//! microseconds of `clone(2)`/futex overhead per dispatch that the
//! paper's "dynamic sparsity must REMOVE work" argument says should not
//! exist.  This pool spawns its workers once (lazily, process-wide) and
//! after that a dispatch is one mutex push + one condvar wake.
//!
//! Contract (identical to the scoped engines it replaces):
//!
//! * Work arrives as row-chunk tasks that write disjoint output slices;
//!   the pool never re-orders arithmetic, so results stay bit-exact for
//!   ANY thread budget — the invariant the serving layer relies on.
//! * [`WorkerPool::run`] blocks until every submitted task finished, so
//!   tasks may borrow from the caller's stack (enforced by the wait, not
//!   the type system — see the `SAFETY` note in `run`).
//! * The caller executes one chunk inline, so a budget of `t` needs only
//!   `t - 1` pool workers and a budget of 1 never touches the pool.
//! * Tasks must be leaf compute: a task that dispatches back onto the
//!   pool can deadlock when every worker is busy.
//!
//! Multiple dispatchers (e.g. concurrent serve workers) share the global
//! pool safely: completion is tracked per dispatch, not per pool.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A borrowed chunk task.  `'env` may be a stack lifetime: `run` does not
/// return until the task has executed.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Per-dispatch completion tracking (tasks from different dispatchers
/// interleave freely in the shared queue).
struct Dispatch {
    remaining: Mutex<usize>,
    done: Condvar,
    /// First caught panic payload, re-raised on the dispatcher so the
    /// original message/location survives (as it did under scoped
    /// threads).
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

struct QueuedTask {
    run: Box<dyn FnOnce() + Send + 'static>,
    dispatch: Arc<Dispatch>,
}

struct PoolState {
    q: VecDeque<QueuedTask>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
}

/// Long-lived worker threads with a chunk-dispatch API.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` background threads (>= 1).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { q: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("dsg-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// The process-wide pool, spawned on first use.  Sized so that ONE
    /// dispatcher at the default budget saturates the machine:
    /// `n_threads() - 1` background workers (the dispatcher runs one
    /// chunk inline), floor 1.  Larger explicit budgets still give
    /// bit-exact results — excess chunks queue and drain as workers
    /// free up.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            WorkerPool::new(super::parallel::n_threads().saturating_sub(1).max(1))
        })
    }

    /// Number of background worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Execute every task to completion.  The last task runs inline on
    /// the calling thread; the rest go to the worker queue.  Blocks
    /// until all tasks have finished (even if one panics), then
    /// propagates the first panic.
    pub fn run(&self, mut tasks: Vec<Task<'_>>) {
        let Some(inline) = tasks.pop() else { return };
        if tasks.is_empty() {
            return inline();
        }
        let dispatch = Arc::new(Dispatch {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            for t in tasks {
                // SAFETY: the loop below blocks until `remaining == 0`,
                // i.e. every queued task has finished running, before
                // this function returns — including when the inline task
                // panics (the payload is re-raised only after the wait).
                // Borrows of `'env` data inside a task therefore never
                // outlive this call, so erasing the lifetime is sound.
                let run: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(t) };
                st.q.push_back(QueuedTask { run, dispatch: dispatch.clone() });
            }
        }
        self.shared.available.notify_all();
        // The dispatcher contributes its own chunk instead of idling.
        let inline_result = catch_unwind(AssertUnwindSafe(inline));
        let mut rem = dispatch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = dispatch.done.wait(rem).unwrap();
        }
        drop(rem);
        if let Err(payload) = inline_result {
            resume_unwind(payload);
        }
        if let Some(payload) = dispatch.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = st.q.pop_front() {
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        // catch_unwind keeps the worker alive across a panicking task;
        // the payload is re-raised on the dispatcher after the drain.
        let result = catch_unwind(AssertUnwindSafe(task.run));
        if let Err(payload) = result {
            let mut slot = task.dispatch.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut rem = task.dispatch.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            task.dispatch.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task<'_>> = (0..17)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn disjoint_slice_writes_land() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0u32; 64];
        {
            let mut tasks: Vec<Task<'_>> = Vec::new();
            let mut rest: &mut [u32] = &mut out;
            for c in 0..8 {
                let (mine, tail) = rest.split_at_mut(8);
                rest = tail;
                tasks.push(Box::new(move || {
                    for (i, v) in mine.iter_mut().enumerate() {
                        *v = (c * 8 + i) as u32;
                    }
                }));
            }
            pool.run(tasks);
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    fn empty_and_single_dispatches() {
        let pool = WorkerPool::new(1);
        pool.run(Vec::new());
        let hit = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            hit.fetch_add(1, Ordering::SeqCst);
        }) as Task<'_>]);
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn reuse_across_many_dispatches() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            let tasks: Vec<Task<'_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        total.fetch_add(1, Ordering::SeqCst);
                    }) as Task<'_>
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(total.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn concurrent_dispatchers_share_the_pool() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..6)
            .map(|_| {
                let pool = pool.clone();
                let total = total.clone();
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        let tasks: Vec<Task<'_>> = (0..3)
                            .map(|_| {
                                Box::new(|| {
                                    total.fetch_add(1, Ordering::SeqCst);
                                })
                                    as Task<'_>
                            })
                            .collect();
                        pool.run(tasks);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 6 * 25 * 3);
    }

    #[test]
    fn queued_task_panic_propagates_after_drain() {
        let pool = WorkerPool::new(2);
        let finished = Arc::new(AtomicUsize::new(0));
        let f2 = finished.clone();
        let f3 = finished.clone();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(vec![
                Box::new(|| panic!("task boom")) as Task<'_>,
                Box::new(move || {
                    f2.fetch_add(1, Ordering::SeqCst);
                }) as Task<'_>,
                Box::new(move || {
                    f3.fetch_add(1, Ordering::SeqCst);
                }) as Task<'_>,
            ]);
        }));
        let payload = result.expect_err("queued-task panic must propagate");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"task boom"), "original payload kept");
        assert_eq!(finished.load(Ordering::SeqCst), 2, "other tasks still ran");
        // the pool survives the panic
        let ok = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::SeqCst);
        }) as Task<'_>]);
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(WorkerPool::global().workers() >= 1);
    }
}
