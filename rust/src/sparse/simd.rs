//! Runtime-detected SIMD primitives for the hot sparse kernels, and the
//! ISA-selection machinery behind [`crate::sparse::parallel::KernelTable`].
//!
//! The crown-jewel invariant of this repo is that the DEFAULT kernels are
//! bit-exact for any thread budget and any dispatch branch.  SIMD cannot
//! join that contract for the dot-product family — an 8-lane vertical
//! accumulation plus one horizontal fold reassociates the sum — so it is
//! packaged as an explicitly opted-in relaxed mode (`--kernels simd`),
//! never a silent upgrade.  The divergence surface is deliberately tiny:
//!
//! * [`Prims::dot`] / [`Prims::dot_sparse`] (the forward masked-VMM
//!   family): 8-lane FMA vertical accumulators, one horizontal fold,
//!   then the scalar 4-aligned-block tail from the 8-aligned boundary.
//!   This is the ONLY place SIMD may differ from the scalar contract,
//!   and the difference is bounded (see `docs/ARCHITECTURE.md`): for a
//!   row of width d the observed |scalar - simd| is within
//!   `4 * d * f32::EPSILON * sum(|x_q * w_q|)`.  When `d < 8` the vector
//!   loop never runs and the result is bit-identical to the scalar
//!   kernel.
//! * [`Prims::axpy`] (the backward dX / gradW accumulate): vectorized
//!   with separate multiply + add (NOT fused), so every output slot sees
//!   exactly the scalar `orow[p] += g * xrow[p]` rounding sequence —
//!   bit-identical, lanes are independent accumulators.
//! * [`bitmask_count_avx2`] (the ZVC bitmask/count pass): `x != 0.0`
//!   evaluated as `_CMP_NEQ_UQ` (unordered-or-not-equal), which matches
//!   the scalar comparison exactly — NaN is nonzero, ±0.0 is zero — so
//!   ZVC compression stays bit-lossless under SIMD.
//!
//! The indexed scatter in `axpy_sparse` stays scalar everywhere: AVX2
//! has gathers but no scatter, and emulating one costs more than the
//! scalar walk.
//!
//! Detection happens once per process ([`active_isa`]): `DSG_SIMD=off`
//! (or `scalar`) forces the portable fallback, anything else defers to
//! `is_x86_feature_detected!("avx2")` + `("fma")`.  Non-x86 builds
//! compile none of the intrinsics and always report [`Isa::Scalar`].

use std::sync::OnceLock;

/// Instruction sets the kernel layer can dispatch to.  `Avx2Fma` is only
/// ever reported on x86/x86_64 after a positive runtime probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels — the bit-exact contract, every target.
    Scalar,
    /// AVX2 + FMA (256-bit, 8 f32 lanes), runtime-detected.
    Avx2Fma,
}

impl Isa {
    /// Stable label for logs / bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2+fma",
        }
    }
}

/// One-chunk ZVC bitmask+count kernel: set bit `i % 8` of `mask[i / 8]`
/// for every nonzero `xs[i]` (mask pre-zeroed by the caller) and return
/// the nonzero count.  [`crate::sparse::parallel::KernelTable`] carries
/// the ISA-selected variant.
pub type BitmaskCountFn = fn(&[f32], &mut [u8]) -> usize;

/// What the hardware supports, ignoring any env override.
pub fn detected_isa() -> Isa {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2Fma;
        }
    }
    Isa::Scalar
}

/// Resolve the `DSG_SIMD` override against the detected ISA — pure, so
/// the forced-fallback rules are unit-testable without touching process
/// env.  `off`/`scalar`/`0` force [`Isa::Scalar`]; `auto`/`on`/`1` (and
/// unset) defer to detection; anything else warns and defers.
pub fn isa_from_env(raw: Option<&str>, detected: Isa) -> (Isa, Option<String>) {
    match raw {
        None => (detected, None),
        Some("off") | Some("scalar") | Some("0") => (Isa::Scalar, None),
        Some("auto") | Some("on") | Some("1") => (detected, None),
        Some(other) => (
            detected,
            Some(format!(
                "DSG_SIMD={other:?} is not a SIMD mode (off | scalar | auto); using runtime detection ({})",
                detected.label()
            )),
        ),
    }
}

/// The ISA the `--kernels simd` mode actually runs on, resolved once per
/// process (like `n_threads`): runtime detection, overridable with
/// `DSG_SIMD=off` for forced-fallback testing and triage.
pub fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        let raw = std::env::var("DSG_SIMD").ok();
        let (isa, warning) = isa_from_env(raw.as_deref(), detected_isa());
        if let Some(w) = warning {
            crate::warn!("{w}");
        }
        isa
    })
}

/// Primitive ops the generic chunk kernels in
/// [`crate::sparse::parallel`] are written against.  `ScalarPrims`
/// (defined next to the scalar helpers it delegates to) reproduces
/// today's bit-exact contract; [`Avx2Prims`] is the relaxed AVX2/FMA
/// set.  Monomorphizing the chunk kernels over this trait is what the
/// per-process dispatch table selects between — no per-call branching
/// inside the kernels.
pub trait Prims {
    const ISA: Isa;

    /// Dense dot product `row . wrow` over `0..d`.
    fn dot(row: &[f32], wrow: &[f32], d: usize) -> f32;

    /// Sparse dot product over the gathered ascending nonzero
    /// coordinates `nz` of `row`.
    fn dot_sparse(nz: &[u32], row: &[f32], wrow: &[f32], d: usize) -> f32;

    /// `orow[p] += g * xrow[p]` for all `p` — independent slots, must be
    /// bit-identical to the scalar loop in every implementation.
    fn axpy(orow: &mut [f32], g: f32, xrow: &[f32]);
}

/// Portable scalar ZVC bitmask/count pass — the reference the SIMD
/// variant is ULP-free-identical to (the comparison is exact either
/// way).  Also the serial path used below the parallel threshold.
pub fn bitmask_count_scalar(xs: &[f32], mask: &mut [u8]) -> usize {
    let mut count = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x != 0.0 {
            mask[i / 8] |= 1 << (i % 8);
            count += 1;
        }
    }
    count
}

// ---------------------------------------------------------------------------
// AVX2/FMA implementations (x86 / x86_64 only)
// ---------------------------------------------------------------------------

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// Deterministic pairwise fold of the 8 vertical accumulator lanes.
    /// The order is fixed (lane L pairs with lane L+4, then a balanced
    /// tree), so a given input always folds to the same bits.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(acc: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
    }

    /// 8-lane FMA dot product + horizontal fold + the scalar
    /// 4-aligned-block tail from the 8-aligned boundary.  For `d < 8`
    /// the vector loop never runs and this is bit-identical to the
    /// scalar `vmm_dot` (the fold of an all-zero accumulator is +0.0,
    /// the same starting value).
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(row: &[f32], wrow: &[f32], d: usize) -> f32 {
        debug_assert!(row.len() >= d && wrow.len() >= d);
        let mut acc = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + 8 <= d {
            let a = _mm256_loadu_ps(row.as_ptr().add(p));
            let b = _mm256_loadu_ps(wrow.as_ptr().add(p));
            acc = _mm256_fmadd_ps(a, b, acc);
            p += 8;
        }
        let mut sum = hsum(acc);
        // the 8-aligned boundary is 4-aligned, so the tail follows the
        // scalar contract's block pattern exactly
        while p + 4 <= d {
            sum += row[p] * wrow[p]
                + row[p + 1] * wrow[p + 1]
                + row[p + 2] * wrow[p + 2]
                + row[p + 3] * wrow[p + 3];
            p += 4;
        }
        while p < d {
            sum += row[p] * wrow[p];
            p += 1;
        }
        sum
    }

    /// Gathered 8-lane FMA dot over the nonzero coordinates: loads 8
    /// indices at a time and `vgatherdps`-fetches both operands.
    /// Indices must fit in i32 (the kernel layer asserts `d <= u32::MAX`
    /// and real layer widths are far below 2^31).
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support at runtime; every
    /// `nz[i]` must be a valid index into both `row` and `wrow`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_sparse(nz: &[u32], row: &[f32], wrow: &[f32]) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= nz.len() {
            let idx = _mm256_loadu_si256(nz.as_ptr().add(i) as *const __m256i);
            let a = _mm256_i32gather_ps::<4>(row.as_ptr(), idx);
            let b = _mm256_i32gather_ps::<4>(wrow.as_ptr(), idx);
            acc = _mm256_fmadd_ps(a, b, acc);
            i += 8;
        }
        let mut sum = hsum(acc);
        while i < nz.len() {
            let q = nz[i] as usize;
            sum += row[q] * wrow[q];
            i += 1;
        }
        sum
    }

    /// Vectorized `orow[p] += g * xrow[p]` with SEPARATE multiply and
    /// add (no FMA): each slot sees exactly the scalar rounding
    /// sequence, so this is bit-identical to the scalar axpy.
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(orow: &mut [f32], g: f32, xrow: &[f32]) {
        let d = orow.len();
        debug_assert!(xrow.len() >= d);
        let gv = _mm256_set1_ps(g);
        let mut p = 0usize;
        while p + 8 <= d {
            let x = _mm256_loadu_ps(xrow.as_ptr().add(p));
            let o = _mm256_loadu_ps(orow.as_ptr().add(p));
            let r = _mm256_add_ps(o, _mm256_mul_ps(gv, x));
            _mm256_storeu_ps(orow.as_mut_ptr().add(p), r);
            p += 8;
        }
        while p < d {
            orow[p] += g * xrow[p];
            p += 1;
        }
    }

    /// Vectorized ZVC bitmask/count: `_CMP_NEQ_UQ` against +0.0 turns 8
    /// lanes into a movemask byte whose bit L is exactly the scalar
    /// `xs[i0 + L] != 0.0` (NaN compares nonzero, ±0.0 compares zero),
    /// so the produced bitmask and count are bit-identical to
    /// [`super::bitmask_count_scalar`].
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn bitmask_count(xs: &[f32], mask: &mut [u8]) -> usize {
        let n = xs.len();
        debug_assert!(mask.len() >= n.div_ceil(8));
        let zero = _mm256_setzero_ps();
        let mut count = 0usize;
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            let neq = _mm256_cmp_ps::<_CMP_NEQ_UQ>(v, zero);
            let bits = _mm256_movemask_ps(neq) as u8;
            mask[i / 8] = bits;
            count += bits.count_ones() as usize;
            i += 8;
        }
        while i < n {
            if xs[i] != 0.0 {
                mask[i / 8] |= 1 << (i % 8);
                count += 1;
            }
            i += 1;
        }
        count
    }
}

/// The AVX2/FMA primitive set.  Instantiations of the generic chunk
/// kernels over this type are only ever reachable through a
/// [`crate::sparse::parallel::KernelTable`] handed out after a positive
/// runtime probe, which is what makes the `unsafe` target-feature calls
/// sound.
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub struct Avx2Prims;

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
impl Prims for Avx2Prims {
    const ISA: Isa = Isa::Avx2Fma;

    #[inline]
    fn dot(row: &[f32], wrow: &[f32], d: usize) -> f32 {
        // SAFETY: reachable only via tables gated on runtime detection
        unsafe { avx2::dot(row, wrow, d) }
    }

    #[inline]
    fn dot_sparse(nz: &[u32], row: &[f32], wrow: &[f32], _d: usize) -> f32 {
        // SAFETY: reachable only via tables gated on runtime detection
        unsafe { avx2::dot_sparse(nz, row, wrow) }
    }

    #[inline]
    fn axpy(orow: &mut [f32], g: f32, xrow: &[f32]) {
        // SAFETY: reachable only via tables gated on runtime detection
        unsafe { avx2::axpy(orow, g, xrow) }
    }
}

/// Safe entry for the AVX2 ZVC pass (the [`BitmaskCountFn`] slot of the
/// AVX2 kernel table).
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub fn bitmask_count_avx2(xs: &[f32], mask: &mut [u8]) -> usize {
    // SAFETY: reachable only via tables gated on runtime detection
    unsafe { avx2::bitmask_count(xs, mask) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_env_override_rules() {
        // forced fallback: the three accepted spellings all force Scalar
        for raw in ["off", "scalar", "0"] {
            let (isa, warn) = isa_from_env(Some(raw), Isa::Avx2Fma);
            assert_eq!(isa, Isa::Scalar);
            assert!(warn.is_none());
        }
        // explicit + implicit auto defer to detection
        for raw in [Some("auto"), Some("on"), Some("1"), None] {
            assert_eq!(isa_from_env(raw, Isa::Avx2Fma), (Isa::Avx2Fma, None));
            assert_eq!(isa_from_env(raw, Isa::Scalar), (Isa::Scalar, None));
        }
        // junk values warn (naming the variable) and defer to detection
        let (isa, warn) = isa_from_env(Some("fast"), Isa::Scalar);
        assert_eq!(isa, Isa::Scalar);
        let w = warn.expect("junk DSG_SIMD must warn");
        assert!(w.contains("DSG_SIMD"), "warning must name the variable: {w}");
    }

    #[test]
    fn scalar_bitmask_counts_nan_and_skips_signed_zero() {
        let xs = [0.0f32, -0.0, f32::NAN, 1.0, f32::MIN_POSITIVE / 2.0, 0.0, -2.0, 0.0, 5.0];
        let mut mask = vec![0u8; 2];
        let nnz = bitmask_count_scalar(&xs, &mut mask);
        assert_eq!(nnz, 5); // NaN + 1.0 + subnormal + -2.0 + 5.0
        assert_eq!(mask[0], 0b0101_1100);
        assert_eq!(mask[1], 0b0000_0001);
    }

    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    #[test]
    fn avx2_bitmask_bit_identical_to_scalar() {
        if detected_isa() != Isa::Avx2Fma {
            return; // nothing to compare against on this host
        }
        let mut xs = Vec::new();
        for i in 0..259 {
            xs.push(match i % 7 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::NAN,
                3 => f32::MIN_POSITIVE / 4.0,
                _ => (i as f32) - 100.0,
            });
        }
        for n in [0usize, 1, 7, 8, 9, 64, 255, 259] {
            let mut a = vec![0u8; n.div_ceil(8)];
            let mut b = vec![0u8; n.div_ceil(8)];
            let ca = bitmask_count_scalar(&xs[..n], &mut a);
            let cb = bitmask_count_avx2(&xs[..n], &mut b);
            assert_eq!(ca, cb, "count at n={n}");
            assert_eq!(a, b, "mask bytes at n={n}");
        }
    }

    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    #[test]
    fn avx2_axpy_bit_identical_to_scalar() {
        if detected_isa() != Isa::Avx2Fma {
            return;
        }
        let mut rng = 0x1234_5678_u64;
        let mut next = move || {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((rng >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for d in [0usize, 1, 3, 7, 8, 9, 15, 16, 33, 100] {
            let x: Vec<f32> = (0..d).map(|_| next()).collect();
            let base: Vec<f32> = (0..d).map(|_| next()).collect();
            let g = next() * 3.0;
            let mut a = base.clone();
            let mut b = base.clone();
            for p in 0..d {
                a[p] += g * x[p];
            }
            Avx2Prims::axpy(&mut b, g, &x);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy bits at d={d}"
            );
        }
    }
}
